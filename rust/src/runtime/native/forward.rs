//! The native forward families — fused fast paths plus the dense reference.
//!
//! Consumes one flat f32 vector per layer unit (the unit of LeZO sparsity)
//! and un-flattens internally, exactly like the AOT'd model executables:
//!
//! ```text
//!   unit 0:            embedding  = [tok_emb (V,D) | pos_emb (S,D)]
//!   units 1..n_layers: block      = [ln1_g, ln1_b, Wq, bq, Wk, bk, Wv, bv,
//!                                    Wo, bo, ln2_g, ln2_b, W1, b1, W2, b2]
//!   unit n_layers+1:   final LN   = [lnf_g, lnf_b]
//! ```
//!
//! Two implementations of the same math live side by side:
//!
//! - **Fast path** ([`mean_loss`], [`example_losses`], [`predict`] and
//!   their `_peft` twins): the blocked, thread-parallel kernels in
//!   [`super::kernels`] drive the transformer into a reusable
//!   [`ForwardScratch`] arena, and the LM head is *fused* — a streaming
//!   per-position logsumexp/argmax over vocab tiles that never
//!   materializes the `rows*seq*vocab` logits tensor.
//! - **Dense reference** ([`forward_logits`] / [`forward_logits_peft`] +
//!   [`position_xent`]): the original scalar loops, kept deliberately
//!   naive. It is the public dense-logits API and the ground truth the
//!   fused paths are tested against (agreement ≤ 1e-4; see the tests
//!   below and `rust/tests/native_backend.rs`).
//!
//! PEFT (the paper's Table 4): under `peft=lora|prefix` the forward takes
//! the frozen base units plus one flat adapter unit per block
//! ([`crate::peft`] documents the layout). LoRA adds
//! `(alpha/r) * (x A) B` to the q/v projections as two skinny matmuls;
//! prefix tuning prepends 5 learned KV positions per block, visible to
//! every query (the causal window applies to real positions only). Both
//! run on the same scratch arena and fused LM head as the base path.
//!
//! Same math as the Pallas/jnp path: pre-LN blocks, causal softmax
//! attention scaled by 1/sqrt(d_head), tanh-approximated GELU, LN eps 1e-5,
//! LM head tied to tok_emb. Numerics are plain f32 with f64 reductions, so
//! losses agree with the XLA path to float tolerance, not bit-for-bit —
//! every *algorithmic* invariant (restore identity, seed reproducibility,
//! MeZO == LeZO at drop 0, thread-count invariance) is exact.

use super::kernels::{
    self, fused_argmax, fused_argmax_bf16, fused_argmax_quant, fused_masked_xent,
    fused_masked_xent_bf16, fused_masked_xent_quant, gelu, peft_block, split_block,
    validate_forward_args, validate_targets, ForwardScratch, PeftBlock, LN_EPS,
};
use super::quant::QuantView;
use crate::model::spec::ModelSpec;
use crate::peft::PeftMode;
use anyhow::Result;

// ---------------------------------------------------------------------------
// Dense reference path (deliberately naive scalar loops)
// ---------------------------------------------------------------------------

/// Row-wise LayerNorm (eps matches kernels/layernorm.py) — reference.
fn layernorm(x: &[f32], gamma: &[f32], beta: &[f32], n_rows: usize, d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n_rows * d];
    for r in 0..n_rows {
        let row = &x[r * d..(r + 1) * d];
        let mean = row.iter().map(|&v| v as f64).sum::<f64>() / d as f64;
        let var = row.iter().map(|&v| (v as f64 - mean) * (v as f64 - mean)).sum::<f64>()
            / d as f64;
        let inv = 1.0 / (var as f32 + LN_EPS).sqrt();
        let o = &mut out[r * d..(r + 1) * d];
        for j in 0..d {
            o[j] = (row[j] - mean as f32) * inv * gamma[j] + beta[j];
        }
    }
    out
}

/// `out[r, o] = b[o] + sum_i x[r, i] * w[i, o]` (w row-major) — reference.
fn matmul_bias(x: &[f32], w: &[f32], b: &[f32], n_rows: usize, din: usize, dout: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n_rows * dout];
    for r in 0..n_rows {
        let orow = &mut out[r * dout..(r + 1) * dout];
        orow.copy_from_slice(b);
        let xrow = &x[r * din..(r + 1) * din];
        for (i, &xi) in xrow.iter().enumerate() {
            let wrow = &w[i * dout..(i + 1) * dout];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += xi * wv;
            }
        }
    }
    out
}

/// Reference LoRA delta: `out += (alpha/r) * (x @ A) @ B`, naive scalar
/// loops (A row-major `(d, r)`, B row-major `(r, d)`).
fn lora_delta_into(out: &mut [f32], x: &[f32], a: &[f32], b: &[f32], n: usize, d: usize) {
    let r = crate::peft::LORA_RANK;
    let scale = (crate::peft::LORA_ALPHA / r as f64) as f32;
    for row in 0..n {
        let xrow = &x[row * d..(row + 1) * d];
        let mut t = vec![0.0f32; r];
        for (i, &xi) in xrow.iter().enumerate() {
            for (j, tv) in t.iter_mut().enumerate() {
                *tv += xi * a[i * r + j];
            }
        }
        let orow = &mut out[row * d..(row + 1) * d];
        for (o, ov) in orow.iter_mut().enumerate() {
            let mut acc = 0.0f32;
            for (j, &tj) in t.iter().enumerate() {
                acc += tj * b[j * d + o];
            }
            *ov += scale * acc;
        }
    }
}

/// Causal multi-head attention + output projection, added into `h`, with
/// the block's PEFT adapter (LoRA q/v deltas; prefix KV positions always
/// visible, before the causal window) — reference.
fn attention_into(
    h: &mut [f32],
    x: &[f32],
    p: &kernels::BlockParams<'_>,
    peft: &PeftBlock<'_>,
    spec: &ModelSpec,
    rows: usize,
    seq: usize,
) {
    let d = spec.d_model;
    let (nh, dh) = (spec.n_heads, spec.d_head());
    let n = rows * seq;
    let mut q = matmul_bias(x, p.wq, p.bq, n, d, d);
    let k = matmul_bias(x, p.wk, p.bk, n, d, d);
    let mut v = matmul_bias(x, p.wv, p.bv, n, d, d);
    let (mut k_pre, mut v_pre): (&[f32], &[f32]) = (&[], &[]);
    match peft {
        PeftBlock::None => {}
        PeftBlock::Lora { a_q, b_q, a_v, b_v } => {
            lora_delta_into(&mut q, x, a_q, b_q, n, d);
            lora_delta_into(&mut v, x, a_v, b_v, n, d);
        }
        PeftBlock::Prefix { k_pre: kp, v_pre: vp } => {
            k_pre = *kp;
            v_pre = *vp;
        }
    }
    let n_pre = k_pre.len() / d;
    let scale = 1.0 / (dh as f32).sqrt();

    let mut ctx = vec![0.0f32; n * d]; // concatenated head outputs
    let mut scores = vec![0.0f32; n_pre + seq];
    for r in 0..rows {
        for head in 0..nh {
            let hoff = head * dh;
            for s1 in 0..seq {
                let qrow = &q[(r * seq + s1) * d + hoff..(r * seq + s1) * d + hoff + dh];
                let visible = n_pre + s1 + 1;
                let mut max = f32::NEG_INFINITY;
                // prefix scores (every query sees all prefix positions)
                for p2 in 0..n_pre {
                    let krow = &k_pre[p2 * d + hoff..p2 * d + hoff + dh];
                    let dot: f32 = qrow.iter().zip(krow).map(|(a, b)| a * b).sum();
                    let s = dot * scale;
                    scores[p2] = s;
                    max = max.max(s);
                }
                // causal scores over real positions s2 <= s1
                for s2 in 0..=s1 {
                    let krow = &k[(r * seq + s2) * d + hoff..(r * seq + s2) * d + hoff + dh];
                    let dot: f32 = qrow.iter().zip(krow).map(|(a, b)| a * b).sum();
                    let s = dot * scale;
                    scores[n_pre + s2] = s;
                    max = max.max(s);
                }
                let mut denom = 0.0f32;
                for sv in scores[..visible].iter_mut() {
                    *sv = (*sv - max).exp();
                    denom += *sv;
                }
                let orow = &mut ctx[(r * seq + s1) * d + hoff..(r * seq + s1) * d + hoff + dh];
                for p2 in 0..n_pre {
                    let w = scores[p2] / denom;
                    let vrow = &v_pre[p2 * d + hoff..p2 * d + hoff + dh];
                    for (o, &vv) in orow.iter_mut().zip(vrow) {
                        *o += w * vv;
                    }
                }
                for s2 in 0..=s1 {
                    let w = scores[n_pre + s2] / denom;
                    let vrow = &v[(r * seq + s2) * d + hoff..(r * seq + s2) * d + hoff + dh];
                    for (o, &vv) in orow.iter_mut().zip(vrow) {
                        *o += w * vv;
                    }
                }
            }
        }
    }
    let proj = matmul_bias(&ctx, p.wo, p.bo, n, d, d);
    for (hv, pv) in h.iter_mut().zip(&proj) {
        *hv += pv;
    }
}

/// `tokens i32[rows, seq] -> logits f32[rows, seq, vocab]` (row-major).
///
/// The public dense-logits path. Deliberately kept as the slow scalar
/// reference: the fused loss/argmax paths are asserted against it.
pub fn forward_logits(
    spec: &ModelSpec,
    units: &[&[f32]],
    tokens: &[i32],
    rows: usize,
    seq: usize,
) -> Result<Vec<f32>> {
    forward_logits_peft(spec, units, PeftMode::Full, &[], tokens, rows, seq)
}

/// Dense reference logits with per-block PEFT adapters — the ground truth
/// the fused PEFT paths are tested against (and an independent scalar
/// implementation of the same math as `python/compile/peft.py`).
pub fn forward_logits_peft(
    spec: &ModelSpec,
    units: &[&[f32]],
    peft: PeftMode,
    peft_units: &[&[f32]],
    tokens: &[i32],
    rows: usize,
    seq: usize,
) -> Result<Vec<f32>> {
    validate_forward_args(spec, units, tokens, rows, seq)?;
    kernels::validate_peft_args(spec, peft, peft_units)?;
    let d = spec.d_model;
    let v = spec.vocab;
    let n = rows * seq;

    let emb = units[0];
    let tok_emb = &emb[..v * d];
    let pos_emb = &emb[v * d..];

    // embed
    let mut h = vec![0.0f32; n * d];
    for r in 0..rows {
        for s in 0..seq {
            let t = tokens[r * seq + s] as usize;
            let hrow = &mut h[(r * seq + s) * d..(r * seq + s + 1) * d];
            let te = &tok_emb[t * d..(t + 1) * d];
            let pe = &pos_emb[s * d..(s + 1) * d];
            for j in 0..d {
                hrow[j] = te[j] + pe[j];
            }
        }
    }

    // blocks
    for l in 0..spec.n_layers {
        let p = split_block(spec, units[1 + l]);
        let pb = match peft {
            PeftMode::Full => PeftBlock::None,
            _ => peft_block(peft, peft_units[l], d),
        };
        let x = layernorm(&h, p.ln1_g, p.ln1_b, n, d);
        attention_into(&mut h, &x, &p, &pb, spec, rows, seq);
        let hm = layernorm(&h, p.ln2_g, p.ln2_b, n, d);
        let mut a = matmul_bias(&hm, p.w1, p.b1, n, d, spec.d_ff());
        for av in a.iter_mut() {
            *av = gelu(*av);
        }
        let m = matmul_bias(&a, p.w2, p.b2, n, spec.d_ff(), d);
        for (hv, mv) in h.iter_mut().zip(&m) {
            *hv += mv;
        }
    }

    // final LN + tied LM head
    let fin = units[spec.n_units() - 1];
    let hf = layernorm(&h, &fin[..d], &fin[d..], n, d);
    let mut logits = vec![0.0f32; n * v];
    for r in 0..n {
        let hrow = &hf[r * d..(r + 1) * d];
        let lrow = &mut logits[r * v..(r + 1) * v];
        for (t, lv) in lrow.iter_mut().enumerate() {
            let erow = &tok_emb[t * d..(t + 1) * d];
            *lv = hrow.iter().zip(erow).map(|(a, b)| a * b).sum();
        }
    }
    Ok(logits)
}

/// Per-position cross-entropy `f32[rows*seq]` over dense logits (stable
/// logsumexp) — the reference the fused head is tested against.
///
/// Out-of-mask positions yield 0 and never touch their target; an in-mask
/// target outside the vocab is a hard error (the old silent clamp scored
/// the wrong token), mirroring [`kernels::validate_targets`] exactly.
pub fn position_xent(
    logits: &[f32],
    targets: &[i32],
    mask: &[f32],
    n: usize,
    vocab: usize,
) -> Result<Vec<f32>> {
    validate_targets(targets, mask, n, vocab)?;
    let mut xent = vec![0.0f32; n];
    for r in 0..n {
        if mask[r] <= 0.0 {
            continue;
        }
        let row = &logits[r * vocab..(r + 1) * vocab];
        let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let sum: f64 = row.iter().map(|&l| ((l - max) as f64).exp()).sum();
        let logz = max as f64 + sum.ln();
        let gold = row[targets[r] as usize] as f64;
        xent[r] = (logz - gold) as f32;
    }
    Ok(xent)
}

// ---------------------------------------------------------------------------
// Fused fast paths (what the backend executes)
// ---------------------------------------------------------------------------

/// Mean LM loss over masked positions — the ZO objective (scalar). Fused:
/// streaming LM head over the hidden states in `scratch`, no logits tensor.
#[allow(clippy::too_many_arguments)]
pub fn mean_loss(
    spec: &ModelSpec,
    units: &[&[f32]],
    tokens: &[i32],
    targets: &[i32],
    mask: &[f32],
    rows: usize,
    seq: usize,
    scratch: &mut ForwardScratch,
) -> Result<f32> {
    mean_loss_peft(spec, units, PeftMode::Full, &[], tokens, targets, mask, rows, seq, scratch)
}

/// [`mean_loss`] with per-block PEFT adapters (Table 4's objective): the
/// adapter-aware [`kernels::forward_hidden_peft`] plus the same fused
/// streaming LM head.
#[allow(clippy::too_many_arguments)]
pub fn mean_loss_peft(
    spec: &ModelSpec,
    units: &[&[f32]],
    peft: PeftMode,
    peft_units: &[&[f32]],
    tokens: &[i32],
    targets: &[i32],
    mask: &[f32],
    rows: usize,
    seq: usize,
    scratch: &mut ForwardScratch,
) -> Result<f32> {
    let n = rows * seq;
    validate_targets(targets, mask, n, spec.vocab)?;
    kernels::forward_hidden_peft(spec, units, peft, peft_units, tokens, rows, seq, scratch)?;
    let d = spec.d_model;
    let tok_emb = &units[0][..spec.vocab * d];
    let ForwardScratch { x, xent, .. } = scratch;
    fused_masked_xent(&x[..n * d], tok_emb, targets, mask, n, spec.vocab, d, &mut xent[..n]);
    // fixed serial reduction: thread-count invariant
    let num: f64 = xent[..n].iter().zip(mask).map(|(&xv, &m)| xv as f64 * m as f64).sum();
    let den: f64 = mask.iter().map(|&m| m as f64).sum::<f64>().max(1.0);
    Ok((num / den) as f32)
}

/// Per-example mean masked loss, `f32[rows]` — option scoring in eval.
/// Fused like [`mean_loss`].
#[allow(clippy::too_many_arguments)]
pub fn example_losses(
    spec: &ModelSpec,
    units: &[&[f32]],
    tokens: &[i32],
    targets: &[i32],
    mask: &[f32],
    rows: usize,
    seq: usize,
    scratch: &mut ForwardScratch,
) -> Result<Vec<f32>> {
    example_losses_peft(
        spec,
        units,
        PeftMode::Full,
        &[],
        tokens,
        targets,
        mask,
        rows,
        seq,
        scratch,
    )
}

/// [`example_losses`] with per-block PEFT adapters.
#[allow(clippy::too_many_arguments)]
pub fn example_losses_peft(
    spec: &ModelSpec,
    units: &[&[f32]],
    peft: PeftMode,
    peft_units: &[&[f32]],
    tokens: &[i32],
    targets: &[i32],
    mask: &[f32],
    rows: usize,
    seq: usize,
    scratch: &mut ForwardScratch,
) -> Result<Vec<f32>> {
    let n = rows * seq;
    validate_targets(targets, mask, n, spec.vocab)?;
    kernels::forward_hidden_peft(spec, units, peft, peft_units, tokens, rows, seq, scratch)?;
    let d = spec.d_model;
    let tok_emb = &units[0][..spec.vocab * d];
    let ForwardScratch { x, xent, .. } = scratch;
    fused_masked_xent(&x[..n * d], tok_emb, targets, mask, n, spec.vocab, d, &mut xent[..n]);
    let mut per = vec![0.0f32; rows];
    for (r, pv) in per.iter_mut().enumerate() {
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for s in 0..seq {
            num += xent[r * seq + s] as f64 * mask[r * seq + s] as f64;
            den += mask[r * seq + s] as f64;
        }
        *pv = (num / den.max(1.0)) as f32;
    }
    Ok(per)
}

/// Greedy next-token prediction at every position, `i32[rows*seq]`.
/// Fused: streaming argmax over vocab tiles, no logits tensor.
pub fn predict(
    spec: &ModelSpec,
    units: &[&[f32]],
    tokens: &[i32],
    rows: usize,
    seq: usize,
    scratch: &mut ForwardScratch,
) -> Result<Vec<i32>> {
    predict_peft(spec, units, PeftMode::Full, &[], tokens, rows, seq, scratch)
}

/// [`predict`] with per-block PEFT adapters.
#[allow(clippy::too_many_arguments)]
pub fn predict_peft(
    spec: &ModelSpec,
    units: &[&[f32]],
    peft: PeftMode,
    peft_units: &[&[f32]],
    tokens: &[i32],
    rows: usize,
    seq: usize,
    scratch: &mut ForwardScratch,
) -> Result<Vec<i32>> {
    let n = rows * seq;
    kernels::forward_hidden_peft(spec, units, peft, peft_units, tokens, rows, seq, scratch)?;
    let d = spec.d_model;
    let tok_emb = &units[0][..spec.vocab * d];
    let mut preds = vec![0i32; n];
    fused_argmax(&scratch.x[..n * d], tok_emb, n, spec.vocab, d, &mut preds);
    Ok(preds)
}

// ---------------------------------------------------------------------------
// bf16 twins of the fused fast paths (precision = bf16)
// ---------------------------------------------------------------------------
//
// Same structure as the f32 families above, executed over the bf16 kernel
// twins: `units` are per-unit bf16 shadows (the backend keeps the f32
// masters authoritative and re-casts touched units — see
// `runtime/native/mod.rs`), activations live in the bf16 half of the
// scratch arena, and PEFT adapter units stay f32. Each kernel is pinned
// bitwise to its f32 twin (kernels.rs tests); the composed forwards here
// are pinned by calibrated tolerances against the f32 path (observed loss
// rel err ~1e-4 across ZO trajectories in the numpy/ml_dtypes twin, vs the
// 1e-2 asserted bound).

/// bf16 twin of [`mean_loss_peft`]: the ZO objective over bf16 shadows.
#[allow(clippy::too_many_arguments)]
pub fn mean_loss_bf16_peft(
    spec: &ModelSpec,
    units: &[&[u16]],
    peft: PeftMode,
    peft_units: &[&[f32]],
    tokens: &[i32],
    targets: &[i32],
    mask: &[f32],
    rows: usize,
    seq: usize,
    scratch: &mut ForwardScratch,
) -> Result<f32> {
    let n = rows * seq;
    validate_targets(targets, mask, n, spec.vocab)?;
    kernels::forward_hidden_bf16_peft(spec, units, peft, peft_units, tokens, rows, seq, scratch)?;
    let d = spec.d_model;
    let tok_emb = &units[0][..spec.vocab * d];
    let ForwardScratch { xb, xent, .. } = scratch;
    fused_masked_xent_bf16(&xb[..n * d], tok_emb, targets, mask, n, spec.vocab, d, &mut xent[..n]);
    // fixed serial reduction: thread-count invariant
    let num: f64 = xent[..n].iter().zip(mask).map(|(&xv, &m)| xv as f64 * m as f64).sum();
    let den: f64 = mask.iter().map(|&m| m as f64).sum::<f64>().max(1.0);
    Ok((num / den) as f32)
}

/// bf16 twin of [`example_losses_peft`].
#[allow(clippy::too_many_arguments)]
pub fn example_losses_bf16_peft(
    spec: &ModelSpec,
    units: &[&[u16]],
    peft: PeftMode,
    peft_units: &[&[f32]],
    tokens: &[i32],
    targets: &[i32],
    mask: &[f32],
    rows: usize,
    seq: usize,
    scratch: &mut ForwardScratch,
) -> Result<Vec<f32>> {
    let n = rows * seq;
    validate_targets(targets, mask, n, spec.vocab)?;
    kernels::forward_hidden_bf16_peft(spec, units, peft, peft_units, tokens, rows, seq, scratch)?;
    let d = spec.d_model;
    let tok_emb = &units[0][..spec.vocab * d];
    let ForwardScratch { xb, xent, .. } = scratch;
    fused_masked_xent_bf16(&xb[..n * d], tok_emb, targets, mask, n, spec.vocab, d, &mut xent[..n]);
    let mut per = vec![0.0f32; rows];
    for (r, pv) in per.iter_mut().enumerate() {
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for s in 0..seq {
            num += xent[r * seq + s] as f64 * mask[r * seq + s] as f64;
            den += mask[r * seq + s] as f64;
        }
        *pv = (num / den.max(1.0)) as f32;
    }
    Ok(per)
}

/// bf16 twin of [`predict_peft`]: streaming argmax over bf16 shadows.
#[allow(clippy::too_many_arguments)]
pub fn predict_bf16_peft(
    spec: &ModelSpec,
    units: &[&[u16]],
    peft: PeftMode,
    peft_units: &[&[f32]],
    tokens: &[i32],
    rows: usize,
    seq: usize,
    scratch: &mut ForwardScratch,
) -> Result<Vec<i32>> {
    let n = rows * seq;
    kernels::forward_hidden_bf16_peft(spec, units, peft, peft_units, tokens, rows, seq, scratch)?;
    let d = spec.d_model;
    let tok_emb = &units[0][..spec.vocab * d];
    let mut preds = vec![0i32; n];
    fused_argmax_bf16(&scratch.xb[..n * d], tok_emb, n, spec.vocab, d, &mut preds);
    Ok(preds)
}

// ---------------------------------------------------------------------------
// quant twins of the fused fast paths (precision = int8 | int4)
// ---------------------------------------------------------------------------
//
// Same structure as the f32 families above, executed over block-quantized
// unit shadows ([`super::quant`]): `units` are per-unit `QuantView`s (the
// backend keeps the f32 masters authoritative and re-quantizes touched
// units — see `runtime/native/mod.rs`), while activations stay f32 and
// share the f32 scratch arena. Each quant kernel decodes weights
// elementwise-exactly and runs the identical f32 inner loop, so every
// family here is **bitwise** equal to its f32 twin run on the dequantized
// units (kernels.rs tests + `rust/tests/kernel_twins.rs`); against the f32
// masters the composed forwards carry quantization error in the weights
// only, pinned by calibrated tolerances in `runtime/native/mod.rs` tests.

/// Quant twin of [`mean_loss_peft`]: the ZO objective over quantized
/// weight shadows (f32 activations, f32 adapters).
#[allow(clippy::too_many_arguments)]
pub fn mean_loss_quant_peft(
    spec: &ModelSpec,
    units: &[QuantView<'_>],
    peft: PeftMode,
    peft_units: &[&[f32]],
    tokens: &[i32],
    targets: &[i32],
    mask: &[f32],
    rows: usize,
    seq: usize,
    scratch: &mut ForwardScratch,
) -> Result<f32> {
    let n = rows * seq;
    validate_targets(targets, mask, n, spec.vocab)?;
    kernels::forward_hidden_quant_peft(spec, units, peft, peft_units, tokens, rows, seq, scratch)?;
    let d = spec.d_model;
    let tok_emb = units[0].split_to(0, spec.vocab * d);
    let ForwardScratch { x, xent, .. } = scratch;
    fused_masked_xent_quant(&x[..n * d], &tok_emb, targets, mask, n, spec.vocab, d, &mut xent[..n]);
    // fixed serial reduction: thread-count invariant
    let num: f64 = xent[..n].iter().zip(mask).map(|(&xv, &m)| xv as f64 * m as f64).sum();
    let den: f64 = mask.iter().map(|&m| m as f64).sum::<f64>().max(1.0);
    Ok((num / den) as f32)
}

/// Quant twin of [`example_losses_peft`].
#[allow(clippy::too_many_arguments)]
pub fn example_losses_quant_peft(
    spec: &ModelSpec,
    units: &[QuantView<'_>],
    peft: PeftMode,
    peft_units: &[&[f32]],
    tokens: &[i32],
    targets: &[i32],
    mask: &[f32],
    rows: usize,
    seq: usize,
    scratch: &mut ForwardScratch,
) -> Result<Vec<f32>> {
    let n = rows * seq;
    validate_targets(targets, mask, n, spec.vocab)?;
    kernels::forward_hidden_quant_peft(spec, units, peft, peft_units, tokens, rows, seq, scratch)?;
    let d = spec.d_model;
    let tok_emb = units[0].split_to(0, spec.vocab * d);
    let ForwardScratch { x, xent, .. } = scratch;
    fused_masked_xent_quant(&x[..n * d], &tok_emb, targets, mask, n, spec.vocab, d, &mut xent[..n]);
    let mut per = vec![0.0f32; rows];
    for (r, pv) in per.iter_mut().enumerate() {
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for s in 0..seq {
            num += xent[r * seq + s] as f64 * mask[r * seq + s] as f64;
            den += mask[r * seq + s] as f64;
        }
        *pv = (num / den.max(1.0)) as f32;
    }
    Ok(per)
}

/// Quant twin of [`predict_peft`]: streaming argmax over the quantized
/// tied embedding.
#[allow(clippy::too_many_arguments)]
pub fn predict_quant_peft(
    spec: &ModelSpec,
    units: &[QuantView<'_>],
    peft: PeftMode,
    peft_units: &[&[f32]],
    tokens: &[i32],
    rows: usize,
    seq: usize,
    scratch: &mut ForwardScratch,
) -> Result<Vec<i32>> {
    let n = rows * seq;
    kernels::forward_hidden_quant_peft(spec, units, peft, peft_units, tokens, rows, seq, scratch)?;
    let d = spec.d_model;
    let tok_emb = units[0].split_to(0, spec.vocab * d);
    let mut preds = vec![0i32; n];
    fused_argmax_quant(&scratch.x[..n * d], &tok_emb, n, spec.vocab, d, &mut preds);
    Ok(preds)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ModelSpec {
        ModelSpec::preset("opt-nano").unwrap()
    }

    fn refs(host: &[Vec<f32>]) -> Vec<&[f32]> {
        host.iter().map(|u| u.as_slice()).collect()
    }

    #[test]
    fn logits_shape_and_finiteness() {
        let s = spec();
        let host = s.init_units(0);
        let (rows, seq) = (2, 8);
        let tokens: Vec<i32> = (0..rows * seq).map(|i| (i % 100) as i32).collect();
        let logits = forward_logits(&s, &refs(&host), &tokens, rows, seq).unwrap();
        assert_eq!(logits.len(), rows * seq * s.vocab);
        assert!(logits.iter().all(|l| l.is_finite()));
    }

    #[test]
    fn loss_near_uniform_at_init() {
        // N(0, 0.02) init: logits are near-uniform, so masked xent must sit
        // close to ln(vocab) — the same sanity the python tests assert.
        let s = spec();
        let host = s.init_units(0);
        let (rows, seq) = (2, 8);
        let tokens: Vec<i32> = (0..rows * seq).map(|i| 20 + (i % 90) as i32).collect();
        let targets: Vec<i32> = tokens.iter().map(|&t| (t + 1) % s.vocab as i32).collect();
        let mask = vec![1.0f32; rows * seq];
        let mut scratch = ForwardScratch::new();
        let loss =
            mean_loss(&s, &refs(&host), &tokens, &targets, &mask, rows, seq, &mut scratch)
                .unwrap();
        let uniform = (s.vocab as f32).ln();
        assert!((loss - uniform).abs() < 0.5, "loss {loss} vs ln(V) {uniform}");
    }

    #[test]
    fn causality_future_tokens_do_not_change_past_logits() {
        let s = spec();
        let host = s.init_units(3);
        let (rows, seq) = (1, 8);
        let mut tokens: Vec<i32> = (0..seq as i32).map(|i| 30 + i).collect();
        let a = forward_logits(&s, &refs(&host), &tokens, rows, seq).unwrap();
        tokens[7] = 400; // change only the last token
        let b = forward_logits(&s, &refs(&host), &tokens, rows, seq).unwrap();
        // positions 0..7 must be bit-identical; position 7 must change
        let v = s.vocab;
        assert_eq!(&a[..7 * v], &b[..7 * v], "past positions leaked the future");
        assert_ne!(&a[7 * v..], &b[7 * v..]);
    }

    /// Dense reference for the fused paths: forward_logits + position_xent.
    fn dense_xent(
        s: &ModelSpec,
        host: &[Vec<f32>],
        tokens: &[i32],
        targets: &[i32],
        mask: &[f32],
        rows: usize,
        seq: usize,
    ) -> Vec<f32> {
        let logits = forward_logits(s, &refs(host), tokens, rows, seq).unwrap();
        position_xent(&logits, targets, mask, rows * seq, s.vocab).unwrap()
    }

    #[test]
    fn fused_mean_loss_matches_dense_reference() {
        let s = spec();
        let host = s.init_units(1);
        let (rows, seq) = (3, 8);
        let tokens: Vec<i32> = (0..rows * seq).map(|i| 20 + (i % 64) as i32).collect();
        let targets: Vec<i32> = tokens.iter().map(|&t| (t + 3) % 512).collect();
        // non-uniform mask: rows get 7 / 4 / 1 active positions
        let mut mask = vec![0.0f32; rows * seq];
        for (r, &count) in [7usize, 4, 1].iter().enumerate() {
            for s2 in 0..count {
                mask[r * seq + s2] = 1.0;
            }
        }
        let xent = dense_xent(&s, &host, &tokens, &targets, &mask, rows, seq);
        let num: f64 = xent.iter().zip(&mask).map(|(&x, &m)| x as f64 * m as f64).sum();
        let den: f64 = mask.iter().map(|&m| m as f64).sum();
        let want = (num / den) as f32;

        let mut scratch = ForwardScratch::new();
        let got =
            mean_loss(&s, &refs(&host), &tokens, &targets, &mask, rows, seq, &mut scratch)
                .unwrap();
        assert!((got - want).abs() <= 1e-4, "fused {got} vs dense {want}");
    }

    #[test]
    fn fused_example_losses_match_dense_and_compose_to_mean_loss() {
        let s = spec();
        let host = s.init_units(1);
        let (rows, seq) = (3, 8);
        let tokens: Vec<i32> = (0..rows * seq).map(|i| 20 + (i % 64) as i32).collect();
        let targets: Vec<i32> = tokens.iter().map(|&t| (t + 3) % 512).collect();
        let mut mask = vec![0.0f32; rows * seq];
        for (r, &count) in [6usize, 3, 2].iter().enumerate() {
            for s2 in 0..count {
                mask[r * seq + s2] = 1.0;
            }
        }
        let xent = dense_xent(&s, &host, &tokens, &targets, &mask, rows, seq);

        let mut scratch = ForwardScratch::new();
        let per =
            example_losses(&s, &refs(&host), &tokens, &targets, &mask, rows, seq, &mut scratch)
                .unwrap();
        let mut num_total = 0.0f64;
        let mut den_total = 0.0f64;
        for r in 0..rows {
            let mut num = 0.0f64;
            let mut den = 0.0f64;
            for s2 in 0..seq {
                num += xent[r * seq + s2] as f64 * mask[r * seq + s2] as f64;
                den += mask[r * seq + s2] as f64;
            }
            let want = (num / den.max(1.0)) as f32;
            assert!((per[r] - want).abs() <= 1e-4, "row {r}: fused {} vs dense {want}", per[r]);
            num_total += per[r] as f64 * den;
            den_total += den;
        }
        // example_losses / mean_loss consistency under the non-uniform mask
        let mean =
            mean_loss(&s, &refs(&host), &tokens, &targets, &mask, rows, seq, &mut scratch)
                .unwrap();
        let recomposed = (num_total / den_total) as f32;
        assert!((recomposed - mean).abs() <= 1e-4, "{recomposed} vs {mean}");
    }

    #[test]
    fn example_losses_match_mean_loss_for_uniform_mask() {
        let s = spec();
        let host = s.init_units(1);
        let (rows, seq) = (3, 8);
        let tokens: Vec<i32> = (0..rows * seq).map(|i| 20 + (i % 64) as i32).collect();
        let targets: Vec<i32> = tokens.iter().map(|&t| (t + 3) % 512).collect();
        let mask = vec![1.0f32; rows * seq];
        let mut scratch = ForwardScratch::new();
        let per =
            example_losses(&s, &refs(&host), &tokens, &targets, &mask, rows, seq, &mut scratch)
                .unwrap();
        let mean =
            mean_loss(&s, &refs(&host), &tokens, &targets, &mask, rows, seq, &mut scratch)
                .unwrap();
        let agg = per.iter().sum::<f32>() / rows as f32;
        assert!((agg - mean).abs() < 1e-4, "{agg} vs {mean}");
    }

    #[test]
    fn predict_is_argmax_of_dense_logits() {
        let s = spec();
        let host = s.init_units(2);
        let (rows, seq) = (1, 4);
        let tokens = vec![10, 11, 12, 13];
        let logits = forward_logits(&s, &refs(&host), &tokens, rows, seq).unwrap();
        let mut scratch = ForwardScratch::new();
        let preds = predict(&s, &refs(&host), &tokens, rows, seq, &mut scratch).unwrap();
        // the fused path recomputes logits with a reordered (vectorized)
        // dot, so compare to the dense argmax with a float tolerance
        for r in 0..seq {
            let row = &logits[r * s.vocab..(r + 1) * s.vocab];
            let best = preds[r] as usize;
            assert!(row.iter().all(|&l| l <= row[best] + 1e-4));
        }
    }

    /// Non-degenerate adapter units: LoRA B blocks are re-randomized (the
    /// unit init zeroes them so step 0 is the base model — useless for
    /// pinning the delta math).
    fn peft_units_nonzero(s: &ModelSpec, mode: crate::peft::PeftMode) -> Vec<Vec<f32>> {
        crate::peft::init_peft_units_nonzero_b(mode, s.n_layers, s.d_model, 9)
    }

    #[test]
    fn fused_peft_losses_match_dense_peft_reference() {
        let s = spec();
        let host = s.init_units(1);
        let (rows, seq) = (2, 8);
        let tokens: Vec<i32> = (0..rows * seq).map(|i| 20 + (i % 64) as i32).collect();
        let targets: Vec<i32> = tokens.iter().map(|&t| (t + 3) % 512).collect();
        let mut mask = vec![0.0f32; rows * seq];
        for (r, &count) in [7usize, 3].iter().enumerate() {
            for s2 in 0..count {
                mask[r * seq + s2] = 1.0;
            }
        }
        for mode in [PeftMode::Lora, PeftMode::Prefix] {
            let peft_host = peft_units_nonzero(&s, mode);
            let peft_refs: Vec<&[f32]> = peft_host.iter().map(|u| u.as_slice()).collect();
            let logits =
                forward_logits_peft(&s, &refs(&host), mode, &peft_refs, &tokens, rows, seq)
                    .unwrap();
            let xent = position_xent(&logits, &targets, &mask, rows * seq, s.vocab).unwrap();
            let num: f64 = xent.iter().zip(&mask).map(|(&x, &m)| x as f64 * m as f64).sum();
            let den: f64 = mask.iter().map(|&m| m as f64).sum();
            let want = (num / den) as f32;

            let mut scratch = ForwardScratch::new();
            let got = mean_loss_peft(
                &s, &refs(&host), mode, &peft_refs, &tokens, &targets, &mask, rows, seq,
                &mut scratch,
            )
            .unwrap();
            assert!((got - want).abs() <= 1e-4, "{mode}: fused {got} vs dense {want}");

            // the adapter must actually change the objective vs the base
            let base =
                mean_loss(&s, &refs(&host), &tokens, &targets, &mask, rows, seq, &mut scratch)
                    .unwrap();
            assert!((got - base).abs() > 1e-6, "{mode}: adapter had no effect ({got} == {base})");

            // per-example fused vs dense, and predict vs dense argmax
            let per = example_losses_peft(
                &s, &refs(&host), mode, &peft_refs, &tokens, &targets, &mask, rows, seq,
                &mut scratch,
            )
            .unwrap();
            for r in 0..rows {
                let mut num = 0.0f64;
                let mut den = 0.0f64;
                for s2 in 0..seq {
                    num += xent[r * seq + s2] as f64 * mask[r * seq + s2] as f64;
                    den += mask[r * seq + s2] as f64;
                }
                let want = (num / den.max(1.0)) as f32;
                assert!((per[r] - want).abs() <= 1e-4, "{mode} row {r}: {} vs {want}", per[r]);
            }
            let preds = predict_peft(
                &s, &refs(&host), mode, &peft_refs, &tokens, rows, seq, &mut scratch,
            )
            .unwrap();
            for p in 0..rows * seq {
                let row = &logits[p * s.vocab..(p + 1) * s.vocab];
                let best = preds[p] as usize;
                assert!(row.iter().all(|&l| l <= row[best] + 1e-4), "{mode} pos {p}");
            }
        }
    }

    #[test]
    fn zero_init_lora_forward_is_bitwise_equal_to_base() {
        // B = 0 at init: every LoRA delta is an exact +0.0, so the adapter
        // forward must reproduce the base hidden states bit for bit.
        let s = spec();
        let host = s.init_units(2);
        let (rows, seq) = (2, 8);
        let tokens: Vec<i32> = (0..rows * seq).map(|i| 10 + (i % 90) as i32).collect();
        let targets: Vec<i32> = tokens.iter().map(|&t| (t + 1) % 512).collect();
        let mask = vec![1.0f32; rows * seq];
        let peft_host =
            crate::peft::init_peft_units(crate::peft::PeftMode::Lora, s.n_layers, s.d_model, 0);
        let peft_refs: Vec<&[f32]> = peft_host.iter().map(|u| u.as_slice()).collect();

        let mut scratch = ForwardScratch::new();
        let base =
            mean_loss(&s, &refs(&host), &tokens, &targets, &mask, rows, seq, &mut scratch)
                .unwrap();
        let lora = mean_loss_peft(
            &s,
            &refs(&host),
            PeftMode::Lora,
            &peft_refs,
            &tokens,
            &targets,
            &mask,
            rows,
            seq,
            &mut scratch,
        )
        .unwrap();
        assert_eq!(base.to_bits(), lora.to_bits(), "zero-adapter LoRA must be the base model");

        // and the dense reference agrees bit for bit too
        let a = forward_logits(&s, &refs(&host), &tokens, rows, seq).unwrap();
        let b = forward_logits_peft(
            &s, &refs(&host), PeftMode::Lora, &peft_refs, &tokens, rows, seq,
        )
        .unwrap();
        assert!(a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn prefix_positions_are_visible_to_every_query() {
        // The prefix changes the logits at position 0 (a purely causal
        // extra position could not), yet real positions stay causal: a
        // future-token edit must not leak into past logits.
        let s = spec();
        let host = s.init_units(3);
        let (rows, seq) = (1, 8);
        let tokens: Vec<i32> = (0..seq as i32).map(|i| 30 + i).collect();
        let peft_host = peft_units_nonzero(&s, PeftMode::Prefix);
        let peft_refs: Vec<&[f32]> = peft_host.iter().map(|u| u.as_slice()).collect();

        let base = forward_logits(&s, &refs(&host), &tokens, rows, seq).unwrap();
        let with_pre = forward_logits_peft(
            &s, &refs(&host), PeftMode::Prefix, &peft_refs, &tokens, rows, seq,
        )
        .unwrap();
        assert_ne!(
            &base[..s.vocab],
            &with_pre[..s.vocab],
            "prefix must be visible at position 0"
        );

        let mut tokens2 = tokens.clone();
        tokens2[7] = 400;
        let with_pre2 = forward_logits_peft(
            &s, &refs(&host), PeftMode::Prefix, &peft_refs, &tokens2, rows, seq,
        )
        .unwrap();
        assert_eq!(
            &with_pre[..7 * s.vocab],
            &with_pre2[..7 * s.vocab],
            "real positions must stay causal under prefix tuning"
        );
    }

    #[test]
    fn peft_shape_errors_are_rejected() {
        let s = spec();
        let host = s.init_units(0);
        let mut scratch = ForwardScratch::new();
        let tokens = vec![1, 2, 3, 4];
        let targets = vec![2, 3, 4, 5];
        let mask = vec![1.0f32; 4];
        // wrong unit count (one per block is required)
        let one = vec![0.0f32; crate::peft::lora_unit_len(s.d_model)];
        let bad_count: Vec<&[f32]> = vec![one.as_slice()];
        assert!(mean_loss_peft(
            &s, &refs(&host), PeftMode::Lora, &bad_count, &tokens, &targets, &mask, 1, 4,
            &mut scratch
        )
        .is_err());
        // wrong unit length
        let short = vec![0.0f32; 3];
        let bad_len: Vec<&[f32]> = (0..s.n_layers).map(|_| short.as_slice()).collect();
        assert!(mean_loss_peft(
            &s, &refs(&host), PeftMode::Prefix, &bad_len, &tokens, &targets, &mask, 1, 4,
            &mut scratch
        )
        .is_err());
        // adapters under peft=full
        let full_extra: Vec<&[f32]> = vec![one.as_slice()];
        assert!(forward_logits_peft(&s, &refs(&host), PeftMode::Full, &full_extra, &tokens, 1, 4)
            .is_err());
    }

    #[test]
    fn in_mask_oov_target_is_a_hard_error() {
        let s = spec();
        let host = s.init_units(0);
        let (rows, seq) = (1, 4);
        let tokens = vec![10, 11, 12, 13];
        let mut targets = vec![11, 12, 13, 0];
        targets[3] = s.vocab as i32 + 7; // out of vocab
        let mut scratch = ForwardScratch::new();
        // masked out: fine (padding rows hold PAD targets beyond range)
        let mask_out = vec![1.0, 1.0, 1.0, 0.0];
        assert!(mean_loss(&s, &refs(&host), &tokens, &targets, &mask_out, rows, seq, &mut scratch)
            .is_ok());
        // in-mask: hard error on both the fused and the dense path
        let mask_in = vec![1.0, 1.0, 1.0, 1.0];
        let err =
            mean_loss(&s, &refs(&host), &tokens, &targets, &mask_in, rows, seq, &mut scratch)
                .unwrap_err();
        assert!(err.to_string().contains("outside the vocab"), "{err}");
        let logits = forward_logits(&s, &refs(&host), &tokens, rows, seq).unwrap();
        assert!(position_xent(&logits, &targets, &mask_in, rows * seq, s.vocab).is_err());
        assert!(position_xent(&logits, &targets, &mask_out, rows * seq, s.vocab).is_ok());
    }

    #[test]
    fn shape_errors_are_rejected() {
        let s = spec();
        let host = s.init_units(0);
        let mut bad = host.clone();
        bad[1].pop();
        assert!(forward_logits(&s, &refs(&bad), &[1, 2], 1, 2).is_err());
        assert!(forward_logits(&s, &refs(&host), &[1, 2, 3], 1, 2).is_err());
        assert!(forward_logits(&s, &refs(&host), &[1, 600], 1, 2).is_err(), "oov token");
    }

    // -- bf16 composed forwards: calibrated tolerances against the f32
    // -- twins. The numpy/ml_dtypes twin observed loss rel err <= 1.1e-4
    // -- across 30-step ZO trajectories on opt-nano (and <= 6.5e-5 with
    // -- weights scaled 8x), so the 1e-2 bounds below have >50x headroom.

    use crate::runtime::native::bf16;

    fn shadows(host: &[Vec<f32>]) -> Vec<Vec<u16>> {
        host.iter().map(|u| bf16::cast(u)).collect()
    }

    fn brefs(sh: &[Vec<u16>]) -> Vec<&[u16]> {
        sh.iter().map(|u| u.as_slice()).collect()
    }

    /// Roughen the init like a mid-run ZO state: one Philox sweep per unit.
    fn perturbed(host: &[Vec<f32>], mu: f32) -> Vec<Vec<f32>> {
        let mut out = host.to_vec();
        for (k, u) in out.iter_mut().enumerate() {
            kernels::axpy_gauss_inplace(u, 1000 + k as u32, mu);
        }
        out
    }

    #[test]
    fn bf16_mean_loss_tracks_f32_within_calibrated_tolerance() {
        let s = spec();
        let (rows, seq) = (3, 8);
        let tokens: Vec<i32> = (0..rows * seq).map(|i| 20 + (i % 64) as i32).collect();
        let targets: Vec<i32> = tokens.iter().map(|&t| (t + 3) % 512).collect();
        let mask = vec![1.0f32; rows * seq];
        let mut scratch = ForwardScratch::new();
        for host in [s.init_units(1), perturbed(&s.init_units(1), 1e-2)] {
            let f = mean_loss(&s, &refs(&host), &tokens, &targets, &mask, rows, seq, &mut scratch)
                .unwrap();
            let sh = shadows(&host);
            let b = mean_loss_bf16_peft(
                &s,
                &brefs(&sh),
                PeftMode::Full,
                &[],
                &tokens,
                &targets,
                &mask,
                rows,
                seq,
                &mut scratch,
            )
            .unwrap();
            let rel = (f - b).abs() / f.abs().max(1e-6);
            assert!(rel <= 1e-2, "bf16 loss {b} vs f32 {f}: rel {rel}");
        }
    }

    #[test]
    fn bf16_example_losses_track_f32_and_compose_to_mean() {
        let s = spec();
        let host = s.init_units(1);
        let sh = shadows(&host);
        let (rows, seq) = (3, 8);
        let tokens: Vec<i32> = (0..rows * seq).map(|i| 20 + (i % 64) as i32).collect();
        let targets: Vec<i32> = tokens.iter().map(|&t| (t + 3) % 512).collect();
        let mut mask = vec![0.0f32; rows * seq];
        for (r, &count) in [6usize, 3, 2].iter().enumerate() {
            for s2 in 0..count {
                mask[r * seq + s2] = 1.0;
            }
        }
        let mut scratch = ForwardScratch::new();
        let f32_per =
            example_losses(&s, &refs(&host), &tokens, &targets, &mask, rows, seq, &mut scratch)
                .unwrap();
        let per = example_losses_bf16_peft(
            &s,
            &brefs(&sh),
            PeftMode::Full,
            &[],
            &tokens,
            &targets,
            &mask,
            rows,
            seq,
            &mut scratch,
        )
        .unwrap();
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for r in 0..rows {
            let rel = (per[r] - f32_per[r]).abs() / f32_per[r].abs().max(1e-6);
            assert!(rel <= 1e-2, "row {r}: bf16 {} vs f32 {}", per[r], f32_per[r]);
            let w: f64 = mask[r * seq..(r + 1) * seq].iter().map(|&m| m as f64).sum();
            num += per[r] as f64 * w;
            den += w;
        }
        let mean = mean_loss_bf16_peft(
            &s,
            &brefs(&sh),
            PeftMode::Full,
            &[],
            &tokens,
            &targets,
            &mask,
            rows,
            seq,
            &mut scratch,
        )
        .unwrap();
        let recomposed = (num / den) as f32;
        assert!((recomposed - mean).abs() <= 1e-4, "{recomposed} vs {mean}");
    }

    #[test]
    fn bf16_predict_is_near_argmax_of_dense_f32_logits() {
        // bf16 can legitimately flip near-ties; assert every bf16 pick is
        // within the calibrated logit perturbation (observed max |delta|
        // 0.0028 at init scale; 0.05 asserted) of the dense f32 argmax.
        let s = spec();
        let host = s.init_units(2);
        let sh = shadows(&host);
        let (rows, seq) = (1, 8);
        let tokens: Vec<i32> = (0..seq as i32).map(|i| 10 + i).collect();
        let logits = forward_logits(&s, &refs(&host), &tokens, rows, seq).unwrap();
        let mut scratch = ForwardScratch::new();
        let preds = predict_bf16_peft(
            &s, &brefs(&sh), PeftMode::Full, &[], &tokens, rows, seq, &mut scratch,
        )
        .unwrap();
        for p in 0..rows * seq {
            let row = &logits[p * s.vocab..(p + 1) * s.vocab];
            let best = preds[p] as usize;
            assert!(row.iter().all(|&l| l <= row[best] + 0.05), "pos {p}");
        }
    }

    #[test]
    fn bf16_zero_init_lora_is_bitwise_equal_to_bf16_base() {
        // the zero-delta exactness carries over to the bf16 path: +0.0 into
        // a widened bf16 value rounds back to the identical bits
        let s = spec();
        let host = s.init_units(2);
        let sh = shadows(&host);
        let (rows, seq) = (2, 8);
        let tokens: Vec<i32> = (0..rows * seq).map(|i| 10 + (i % 90) as i32).collect();
        let targets: Vec<i32> = tokens.iter().map(|&t| (t + 1) % 512).collect();
        let mask = vec![1.0f32; rows * seq];
        let peft_host =
            crate::peft::init_peft_units(crate::peft::PeftMode::Lora, s.n_layers, s.d_model, 0);
        let peft_refs: Vec<&[f32]> = peft_host.iter().map(|u| u.as_slice()).collect();
        let mut scratch = ForwardScratch::new();
        let base = mean_loss_bf16_peft(
            &s,
            &brefs(&sh),
            PeftMode::Full,
            &[],
            &tokens,
            &targets,
            &mask,
            rows,
            seq,
            &mut scratch,
        )
        .unwrap();
        let lora = mean_loss_bf16_peft(
            &s,
            &brefs(&sh),
            PeftMode::Lora,
            &peft_refs,
            &tokens,
            &targets,
            &mask,
            rows,
            seq,
            &mut scratch,
        )
        .unwrap();
        assert_eq!(base.to_bits(), lora.to_bits(), "zero-adapter bf16 LoRA must be the base");
    }

    #[test]
    fn bf16_peft_losses_track_f32_peft_within_tolerance() {
        let s = spec();
        let host = s.init_units(1);
        let sh = shadows(&host);
        let (rows, seq) = (2, 8);
        let tokens: Vec<i32> = (0..rows * seq).map(|i| 20 + (i % 64) as i32).collect();
        let targets: Vec<i32> = tokens.iter().map(|&t| (t + 3) % 512).collect();
        let mask = vec![1.0f32; rows * seq];
        let mut scratch = ForwardScratch::new();
        for mode in [PeftMode::Lora, PeftMode::Prefix] {
            let peft_host = peft_units_nonzero(&s, mode);
            let peft_refs: Vec<&[f32]> = peft_host.iter().map(|u| u.as_slice()).collect();
            let f = mean_loss_peft(
                &s, &refs(&host), mode, &peft_refs, &tokens, &targets, &mask, rows, seq,
                &mut scratch,
            )
            .unwrap();
            let b = mean_loss_bf16_peft(
                &s, &brefs(&sh), mode, &peft_refs, &tokens, &targets, &mask, rows, seq,
                &mut scratch,
            )
            .unwrap();
            let rel = (f - b).abs() / f.abs().max(1e-6);
            assert!(rel <= 1e-2, "{mode}: bf16 {b} vs f32 {f} (rel {rel})");
            // the adapter must still move the bf16 objective vs its base
            let base = mean_loss_bf16_peft(
                &s,
                &brefs(&sh),
                PeftMode::Full,
                &[],
                &tokens,
                &targets,
                &mask,
                rows,
                seq,
                &mut scratch,
            )
            .unwrap();
            assert!((b - base).abs() > 1e-6, "{mode}: adapter had no effect in bf16");
        }
    }

    #[test]
    fn bf16_in_mask_oov_target_is_a_hard_error_too() {
        let s = spec();
        let sh = shadows(&s.init_units(0));
        let tokens = vec![10, 11, 12, 13];
        let mut targets = vec![11, 12, 13, 0];
        targets[3] = s.vocab as i32 + 7;
        let mask = vec![1.0f32; 4];
        let mut scratch = ForwardScratch::new();
        let err = mean_loss_bf16_peft(
            &s,
            &brefs(&sh),
            PeftMode::Full,
            &[],
            &tokens,
            &targets,
            &mask,
            1,
            4,
            &mut scratch,
        )
        .unwrap_err();
        assert!(err.to_string().contains("outside the vocab"), "{err}");
    }

    // -- quant twins: each fused family must be BITWISE equal to its f32
    // -- twin run on the dequantized units (the composed-forward tolerance
    // -- pins against the f32 *masters* live in runtime/native/mod.rs).

    #[test]
    fn quant_families_are_bitwise_equal_to_f32_families_on_dequantized_units() {
        use crate::runtime::native::quant::{self, QuantMode, QuantView};
        let s = spec();
        let host = s.init_units(3);
        let (rows, seq) = (2usize, 8usize);
        let tokens: Vec<i32> = (0..rows * seq).map(|i| 15 + (i % 95) as i32).collect();
        let targets: Vec<i32> = (0..rows * seq).map(|i| 5 + (i % 100) as i32).collect();
        let mut mask = vec![1.0f32; rows * seq];
        mask[2] = 0.0;
        let mut scratch = ForwardScratch::new();
        for mode in [QuantMode::Int8, QuantMode::Int4] {
            let pairs: Vec<(Vec<f32>, Vec<u8>)> =
                host.iter().map(|u| quant::quantize(mode, u).unwrap()).collect();
            let views: Vec<QuantView<'_>> = pairs
                .iter()
                .zip(&host)
                .map(|((sc, c), u)| QuantView::new(mode, sc, c, u.len()))
                .collect();
            let deq: Vec<Vec<f32>> = views.iter().map(|v| v.dequant()).collect();
            let deq_refs: Vec<&[f32]> = deq.iter().map(|u| u.as_slice()).collect();

            let lq = mean_loss_quant_peft(
                &s, &views, PeftMode::Full, &[], &tokens, &targets, &mask, rows, seq,
                &mut scratch,
            )
            .unwrap();
            let lf = mean_loss_peft(
                &s, &deq_refs, PeftMode::Full, &[], &tokens, &targets, &mask, rows, seq,
                &mut scratch,
            )
            .unwrap();
            assert_eq!(lq.to_bits(), lf.to_bits(), "{mode} mean_loss");

            let eq = example_losses_quant_peft(
                &s, &views, PeftMode::Full, &[], &tokens, &targets, &mask, rows, seq,
                &mut scratch,
            )
            .unwrap();
            let ef = example_losses_peft(
                &s, &deq_refs, PeftMode::Full, &[], &tokens, &targets, &mask, rows, seq,
                &mut scratch,
            )
            .unwrap();
            assert_eq!(
                eq.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                ef.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "{mode} example_losses"
            );

            let pq = predict_quant_peft(
                &s, &views, PeftMode::Full, &[], &tokens, rows, seq, &mut scratch,
            )
            .unwrap();
            let pf =
                predict_peft(&s, &deq_refs, PeftMode::Full, &[], &tokens, rows, seq, &mut scratch)
                    .unwrap();
            assert_eq!(pq, pf, "{mode} predict");
        }
    }
}

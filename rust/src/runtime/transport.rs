//! Fault-tolerant framed socket transport for the sharded backend
//! (`shard_transport=socket`): the coordinator talks to `lezo worker
//! --listen <addr>` processes over length-prefixed, versioned, CRC-32'd
//! frames carrying the existing [`StepPlan`] scalars out and `(eval idx,
//! f64 loss)` scalars back.
//!
//! ## Why scalars are enough
//!
//! The MeZO/LeZO seed-regeneration invariant means a ZO step is fully
//! described by its [`StepPlan`]: every perturbation is regenerated from a
//! `(step, probe, unit)` seed inside the worker's own zo_axpy kernel.
//! Workers hold a full lockstep copy of the parameters (built once at
//! `INIT`, mutated only by broadcast sweeps and uploads), so the per-step
//! wire traffic is a few hundred bytes of plan scalars each way — never
//! parameters, never gradients.
//!
//! ## Frame layout (mirrors the `model/checkpoint.rs` section envelope)
//!
//! ```text
//!   handshake, both directions, unframed:
//!       b"LEZOWIRE" | version u32 LE
//!   frame:
//!       tag [u8;4] | len u64 LE | payload [len bytes] | crc32(payload) u32 LE
//! ```
//!
//! Every request payload begins with a `req_id u64`; every reply echoes
//! it. The worker keeps its last `(req_id, reply)` pair, so a retried
//! request (after a timeout, a dropped connection, or a CRC-rejected
//! reply) is served from that cache and **never executed twice** — retries
//! are idempotent by construction, which is what makes "reconnect and
//! resend" a safe universal recovery policy.
//!
//! ## Liveness and failure policy
//!
//! - Every socket operation (connect, read, write) runs under an explicit
//!   timeout — there are no unbounded waits anywhere in this module.
//! - During plan execution the worker emits `HBEA` heartbeat frames every
//!   ~200ms from a side thread; the coordinator's reply reader skips them,
//!   and each one refreshes the read timeout, so a long forward never looks
//!   like a dead peer while an actually-dead peer is detected within one
//!   timeout window.
//! - Transport errors (timeout, EOF, CRC mismatch, connect failure) are
//!   retried with bounded backoff ([`crate::util::retry_with_backoff_deadline`]).
//!   When retries are exhausted the worker is declared **dead** and the
//!   coordinator degrades: remaining evals are re-partitioned over the
//!   survivors (see `RemotePool::run_plan`) and the run continues — or
//!   halts with a named error if no workers remain.
//! - `FAIL` replies are application errors (the worker executed and
//!   failed); they are **not** retried and surface as named hard errors.
//!
//! ## Deterministic transport faults (`faults` grammar, worker-side)
//!
//! `net-drop@K` (execute, cache the reply, close without replying once),
//! `net-delay@K:ms` (stall before compute, before heartbeats start),
//! `net-corrupt@K` (send the reply with a corrupted CRC once — the
//! coordinator must reject and re-fetch, never consume it), and
//! `worker-crash@K:shard` (the matching worker exits at plan receipt).
//! All are keyed on the 1-based step (`plan.step + 1`) and injected by the
//! worker, so runs are reproducible byte-for-byte.

use crate::data::batch::Batch;
use crate::peft::PeftMode;
use crate::runtime::backend::{Backend, Precision};
use crate::runtime::native::{NativeBackend, NativeBuf};
use crate::runtime::plan::{EvalSpec, PlanPhase, StepPlan, SweepOp};
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::collections::{BTreeSet, HashMap, HashSet};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

/// Magic prefix of the unframed handshake both peers send on connect.
pub const WIRE_MAGIC: &[u8; 8] = b"LEZOWIRE";
/// Wire protocol version; a mismatch is rejected at handshake.
pub const WIRE_VERSION: u32 = 1;
/// Hard cap on a single frame payload (a corrupted length field must not
/// trigger a giant allocation).
pub const MAX_FRAME: u64 = 1 << 30;

/// Default per-request socket timeout (`net_timeout_ms` config key).
pub const DEFAULT_NET_TIMEOUT_MS: u64 = 5_000;
/// Default bounded retry count per request (`net_retries` config key).
pub const DEFAULT_NET_RETRIES: u32 = 3;

const HEARTBEAT_TICK_MS: u64 = 50;
const HEARTBEAT_EVERY_TICKS: u32 = 4; // one HBEA per ~200ms of compute

// request tags (coordinator -> worker)
pub const T_INIT: [u8; 4] = *b"INIT";
pub const T_UPLD: [u8; 4] = *b"UPLD";
pub const T_FREE: [u8; 4] = *b"FREE";
pub const T_AXPY: [u8; 4] = *b"AXPY"; // in-place seeded sweep
pub const T_AXPM: [u8; 4] = *b"AXPM"; // in-place masked sweep
pub const T_AXPN: [u8; 4] = *b"AXPN"; // allocating sweep into a new id
pub const T_AXMN: [u8; 4] = *b"AXMN"; // allocating masked sweep
pub const T_PLAN: [u8; 4] = *b"PLAN";
pub const T_PING: [u8; 4] = *b"PING";
pub const T_SHUT: [u8; 4] = *b"SHUT";
// reply tags (worker -> coordinator)
pub const T_OKAY: [u8; 4] = *b"OKAY";
pub const T_LOSS: [u8; 4] = *b"LOSS";
pub const T_PONG: [u8; 4] = *b"PONG";
pub const T_FAIL: [u8; 4] = *b"FAIL";
pub const T_HBEA: [u8; 4] = *b"HBEA";

fn tag_name(tag: &[u8; 4]) -> String {
    String::from_utf8_lossy(tag).into_owned()
}

/// IEEE CRC-32 (poly 0xEDB8_8320) — byte-identical to the checkpoint
/// envelope's checksum, table-free on purpose (cold path, tiny frames).
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

// ---------------------------------------------------------------------------
// byte cursor (the checkpoint.rs named-offset-error discipline)
// ---------------------------------------------------------------------------

/// Byte cursor over a frame payload: every under-run is a hard error naming
/// the decode context and the exact byte offset, never a panic.
pub struct Cur<'a> {
    data: &'a [u8],
    off: usize,
    label: String,
}

impl<'a> Cur<'a> {
    pub fn new(data: &'a [u8], label: impl Into<String>) -> Cur<'a> {
        Cur { data, off: 0, label: label.into() }
    }

    pub fn remaining(&self) -> usize {
        self.data.len() - self.off
    }

    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let have = self.remaining();
        ensure!(
            n <= have,
            "{}: truncated at byte offset {} (need {} more bytes, {} left of {})",
            self.label,
            self.off,
            n,
            have,
            self.data.len()
        );
        let out = &self.data[self.off..self.off + n];
        self.off += n;
        Ok(out)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn i32(&mut self) -> Result<i32> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Length-prefixed UTF-8 string (`len u64 | bytes`).
    pub fn str_(&mut self) -> Result<String> {
        let n = self.len_prefix(1)?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| anyhow!("{}: string at byte offset {} is not UTF-8", self.label, self.off))
    }

    /// A `len u64` whose implied byte size must still fit in the payload —
    /// rejects implausible lengths before any allocation.
    fn len_prefix(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.u64()? as usize;
        let need = n.checked_mul(elem_bytes).ok_or_else(|| {
            anyhow!("{}: implausible array length {} at byte offset {}", self.label, n, self.off)
        })?;
        ensure!(
            need <= self.remaining(),
            "{}: truncated at byte offset {} (need {} more bytes, {} left of {})",
            self.label,
            self.off,
            need,
            self.remaining(),
            self.data.len()
        );
        Ok(n)
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.len_prefix(4)?;
        let bytes = self.take(n * 4)?;
        Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    pub fn i32s(&mut self) -> Result<Vec<i32>> {
        let n = self.len_prefix(4)?;
        let bytes = self.take(n * 4)?;
        Ok(bytes.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    pub fn u64s(&mut self) -> Result<Vec<u64>> {
        let n = self.len_prefix(8)?;
        let bytes = self.take(n * 8)?;
        Ok(bytes.chunks_exact(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    /// Assert the payload is fully consumed — trailing bytes mean a codec
    /// mismatch, which must be loud, not silently ignored.
    pub fn finish(self) -> Result<()> {
        ensure!(
            self.remaining() == 0,
            "{}: {} trailing bytes after decode (codec mismatch?)",
            self.label,
            self.remaining()
        );
        Ok(())
    }
}

// little-endian encode helpers (the write-side mirror of `Cur`)
pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}
pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}
pub fn put_i32(out: &mut Vec<u8>, v: i32) {
    out.extend_from_slice(&v.to_le_bytes());
}
pub fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}
pub fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}
pub fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u64(out, s.len() as u64);
    out.extend_from_slice(s.as_bytes());
}
pub fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    put_u64(out, xs.len() as u64);
    for &x in xs {
        put_f32(out, x);
    }
}
pub fn put_i32s(out: &mut Vec<u8>, xs: &[i32]) {
    put_u64(out, xs.len() as u64);
    for &x in xs {
        put_i32(out, x);
    }
}
pub fn put_u64s(out: &mut Vec<u8>, xs: &[u64]) {
    put_u64(out, xs.len() as u64);
    for &x in xs {
        put_u64(out, x);
    }
}

// ---------------------------------------------------------------------------
// frames
// ---------------------------------------------------------------------------

/// Serialize one frame: `tag | len u64 | payload | crc32(payload)`.
pub fn frame_bytes(tag: &[u8; 4], payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(16 + payload.len());
    out.extend_from_slice(tag);
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(payload);
    out.extend_from_slice(&crc32(payload).to_le_bytes());
    out
}

/// Write one frame as a single `write_all` (so a concurrent heartbeat
/// thread can never interleave bytes inside a frame).
pub fn write_frame(w: &mut impl Write, tag: &[u8; 4], payload: &[u8]) -> Result<()> {
    w.write_all(&frame_bytes(tag, payload))
        .with_context(|| format!("writing '{}' frame failed or timed out", tag_name(tag)))?;
    Ok(())
}

/// Decode one frame from a byte slice (pure, for tests and buffers):
/// truncation at any byte boundary and any CRC mismatch are named errors.
pub fn decode_frame(bytes: &[u8], label: &str) -> Result<([u8; 4], Vec<u8>)> {
    let mut cur = Cur::new(bytes, label);
    let tag: [u8; 4] = cur.take(4)?.try_into().unwrap();
    let len = cur.u64()?;
    ensure!(
        len <= MAX_FRAME,
        "{label}: frame '{}' length {len} exceeds the {MAX_FRAME}-byte cap",
        tag_name(&tag)
    );
    let payload = cur.take(len as usize)?.to_vec();
    let stored = cur.u32()?;
    let computed = crc32(&payload);
    ensure!(
        stored == computed,
        "{label}: frame '{}' payload CRC mismatch (stored {stored:#010x}, computed {computed:#010x})",
        tag_name(&tag)
    );
    Ok((tag, payload))
}

/// Read one frame from a stream. `Ok(None)` is a clean close at a frame
/// boundary; EOF mid-frame, a read timeout, an oversized length, or a CRC
/// mismatch are errors (a CRC-rejected frame is never returned to the
/// caller — the connection is abandoned and the request retried).
pub fn read_frame_opt(r: &mut impl Read, label: &str) -> Result<Option<([u8; 4], Vec<u8>)>> {
    let mut head = [0u8; 12];
    match r.read(&mut head[..1]) {
        Ok(0) => return Ok(None),
        Ok(_) => {}
        Err(e) => return Err(anyhow!(e).context(format!("{label}: socket read failed or timed out"))),
    }
    r.read_exact(&mut head[1..])
        .with_context(|| format!("{label}: connection lost mid-frame header"))?;
    let tag: [u8; 4] = head[..4].try_into().unwrap();
    let len = u64::from_le_bytes(head[4..12].try_into().unwrap());
    ensure!(
        len <= MAX_FRAME,
        "{label}: frame '{}' length {len} exceeds the {MAX_FRAME}-byte cap",
        tag_name(&tag)
    );
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)
        .with_context(|| format!("{label}: connection lost mid-payload of '{}'", tag_name(&tag)))?;
    let mut crc = [0u8; 4];
    r.read_exact(&mut crc)
        .with_context(|| format!("{label}: connection lost before CRC of '{}'", tag_name(&tag)))?;
    let stored = u32::from_le_bytes(crc);
    let computed = crc32(&payload);
    ensure!(
        stored == computed,
        "{label}: frame '{}' payload CRC mismatch (stored {stored:#010x}, computed {computed:#010x})",
        tag_name(&tag)
    );
    Ok(Some((tag, payload)))
}

/// Like [`read_frame_opt`] but a clean close is also an error (the caller
/// was waiting for a reply).
pub fn read_frame(r: &mut impl Read, label: &str) -> Result<([u8; 4], Vec<u8>)> {
    read_frame_opt(r, label)?.ok_or_else(|| anyhow!("{label}: connection closed by peer"))
}

/// Send our side of the handshake (`LEZOWIRE` + version, unframed).
pub fn write_hello(w: &mut impl Write) -> Result<()> {
    let mut buf = Vec::with_capacity(12);
    buf.extend_from_slice(WIRE_MAGIC);
    buf.extend_from_slice(&WIRE_VERSION.to_le_bytes());
    w.write_all(&buf).context("handshake write failed or timed out")?;
    Ok(())
}

/// Read and verify the peer's handshake: wrong magic and version mismatch
/// are distinct named errors.
pub fn expect_hello(r: &mut impl Read, label: &str) -> Result<()> {
    let mut buf = [0u8; 12];
    r.read_exact(&mut buf)
        .with_context(|| format!("{label}: connection closed during handshake"))?;
    ensure!(
        &buf[..8] == WIRE_MAGIC,
        "{label}: peer is not a lezo wire endpoint (bad magic {:02x?})",
        &buf[..8]
    );
    let v = u32::from_le_bytes(buf[8..12].try_into().unwrap());
    ensure!(
        v == WIRE_VERSION,
        "{label}: wire version mismatch — peer speaks v{v}, this build speaks v{WIRE_VERSION}"
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// StepPlan / Batch codecs
// ---------------------------------------------------------------------------

fn put_ops(out: &mut Vec<u8>, ops: &[SweepOp]) {
    put_u64(out, ops.len() as u64);
    for op in ops {
        put_u64(out, op.unit as u64);
        put_u64(out, op.len as u64);
        put_i32(out, op.seed);
        put_f32(out, op.coeff);
    }
}

fn ops_from(cur: &mut Cur) -> Result<Vec<SweepOp>> {
    let n = cur.u64()? as usize;
    ensure!(n <= 1 << 24, "implausible sweep-op count {n}");
    let mut ops = Vec::with_capacity(n);
    for _ in 0..n {
        ops.push(SweepOp {
            unit: cur.u64()? as usize,
            len: cur.u64()? as usize,
            seed: cur.i32()?,
            coeff: cur.f32()?,
        });
    }
    Ok(ops)
}

/// Serialize a [`StepPlan`] — scalars only, deterministic byte-for-byte
/// (f32 coefficients travel as their exact bit patterns).
pub fn encode_plan(plan: &StepPlan) -> Vec<u8> {
    use crate::coordinator::optim::ProbeSchedule;
    let mut out = Vec::new();
    put_u64(&mut out, plan.step);
    match plan.schedule {
        ProbeSchedule::TwoSided => out.push(0),
        ProbeSchedule::OneSided { probes } => {
            out.push(1);
            put_u64(&mut out, probes as u64);
        }
    }
    put_u64(&mut out, plan.phases.len() as u64);
    for phase in &plan.phases {
        match phase {
            PlanPhase::Sweep(ops) => {
                out.push(0);
                put_ops(&mut out, ops);
            }
            PlanPhase::Eval { idx } => {
                out.push(1);
                put_u64(&mut out, *idx as u64);
            }
        }
    }
    put_u64(&mut out, plan.evals.len() as u64);
    for e in &plan.evals {
        put_u64(&mut out, e.probe);
    }
    put_u64(&mut out, plan.recovery.len() as u64);
    for ops in &plan.recovery {
        put_ops(&mut out, ops);
    }
    out
}

/// Decode a [`StepPlan`] (consumes exactly what [`encode_plan`] wrote).
pub fn decode_plan(cur: &mut Cur) -> Result<StepPlan> {
    use crate::coordinator::optim::ProbeSchedule;
    let step = cur.u64()?;
    let schedule = match cur.u8()? {
        0 => ProbeSchedule::TwoSided,
        1 => ProbeSchedule::OneSided { probes: cur.u64()? as usize },
        t => bail!("unknown probe-schedule tag {t} in plan"),
    };
    let n_phases = cur.u64()? as usize;
    ensure!(n_phases <= 1 << 24, "implausible phase count {n_phases}");
    let mut phases = Vec::with_capacity(n_phases);
    for _ in 0..n_phases {
        phases.push(match cur.u8()? {
            0 => PlanPhase::Sweep(ops_from(cur)?),
            1 => PlanPhase::Eval { idx: cur.u64()? as usize },
            t => bail!("unknown plan-phase tag {t}"),
        });
    }
    let n_evals = cur.u64()? as usize;
    ensure!(n_evals <= 1 << 24, "implausible eval count {n_evals}");
    let mut evals = Vec::with_capacity(n_evals);
    for _ in 0..n_evals {
        evals.push(EvalSpec { probe: cur.u64()? });
    }
    let n_rec = cur.u64()? as usize;
    ensure!(n_rec <= 1 << 24, "implausible recovery count {n_rec}");
    let mut recovery = Vec::with_capacity(n_rec);
    for _ in 0..n_rec {
        recovery.push(ops_from(cur)?);
    }
    Ok(StepPlan { step, schedule, phases, evals, recovery })
}

/// Serialize a [`Batch`] (`rows | seq | tokens | targets | mask`).
pub fn encode_batch_into(out: &mut Vec<u8>, batch: &Batch) {
    put_u64(out, batch.rows as u64);
    put_u64(out, batch.seq as u64);
    put_i32s(out, &batch.tokens);
    put_i32s(out, &batch.targets);
    put_f32s(out, &batch.mask);
}

/// Decode a [`Batch`] with shape plausibility checks.
pub fn decode_batch(cur: &mut Cur) -> Result<Batch> {
    let rows = cur.u64()? as usize;
    let seq = cur.u64()? as usize;
    let tokens = cur.i32s()?;
    let targets = cur.i32s()?;
    let mask = cur.f32s()?;
    let n = rows
        .checked_mul(seq)
        .ok_or_else(|| anyhow!("implausible batch shape {rows}x{seq}"))?;
    ensure!(
        tokens.len() == n && targets.len() == n && mask.len() == n,
        "batch shape {rows}x{seq} does not match its arrays ({}/{}/{})",
        tokens.len(),
        targets.len(),
        mask.len()
    );
    Ok(Batch { tokens, targets, mask, rows, seq })
}

// ---------------------------------------------------------------------------
// env knobs (LEZO_THREADS strictness rule: unset/empty = no override,
// unparseable = hard error naming the variable)
// ---------------------------------------------------------------------------

/// `LEZO_NET_TIMEOUT_MS`: env override for the `net_timeout_ms` config key.
pub fn env_net_timeout_ms() -> Result<Option<u64>> {
    let v = std::env::var("LEZO_NET_TIMEOUT_MS").unwrap_or_default();
    if v.is_empty() {
        return Ok(None);
    }
    match v.parse::<u64>() {
        Ok(n) if n > 0 => Ok(Some(n)),
        _ => Err(anyhow!(
            "LEZO_NET_TIMEOUT_MS='{v}' is not a positive per-request timeout in milliseconds \
             (unset it to use the `net_timeout_ms` config key)"
        )),
    }
}

/// Resolve the per-request socket timeout: env wins over the config key.
pub fn resolve_net_timeout_ms(requested: u64) -> Result<u64> {
    let n = env_net_timeout_ms()?.unwrap_or(requested);
    ensure!(
        n > 0,
        "net_timeout_ms must be a positive number of milliseconds (got {n}; set the \
         `net_timeout_ms` config key or LEZO_NET_TIMEOUT_MS to an integer >= 1)"
    );
    Ok(n)
}

/// `LEZO_NET_RETRIES`: env override for the `net_retries` config key.
pub fn env_net_retries() -> Result<Option<u32>> {
    let v = std::env::var("LEZO_NET_RETRIES").unwrap_or_default();
    if v.is_empty() {
        return Ok(None);
    }
    match v.parse::<u32>() {
        Ok(n) if n > 0 => Ok(Some(n)),
        _ => Err(anyhow!(
            "LEZO_NET_RETRIES='{v}' is not a positive request attempt count \
             (unset it to use the `net_retries` config key)"
        )),
    }
}

/// Resolve the bounded per-request attempt count: env wins over config.
pub fn resolve_net_retries(requested: u32) -> Result<u32> {
    let n = env_net_retries()?.unwrap_or(requested);
    ensure!(
        n > 0,
        "net_retries must be a positive attempt count (got {n}; set the `net_retries` \
         config key or LEZO_NET_RETRIES to an integer >= 1)"
    );
    Ok(n)
}

// ---------------------------------------------------------------------------
// coordinator side: WorkerClient + RemotePool
// ---------------------------------------------------------------------------

/// Everything the coordinator needs to stand up a socket-mode pool.
#[derive(Debug, Clone)]
pub struct SocketOpts {
    /// Worker addresses, one per shard (`workers` config key).
    pub workers: Vec<String>,
    /// Model name sent in `INIT` (each worker rebuilds the same replica).
    pub model: String,
    pub precision: Precision,
    /// Artifact dir for spec resolution; empty = in-crate preset.
    pub artifact_dir: String,
    /// The run's effective faults string (workers act on the net-* kinds).
    pub faults: String,
    pub timeout_ms: u64,
    pub retries: u32,
}

enum PlanOutcome {
    /// `(eval idx, loss)` pairs, worker compute seconds, request round-trip
    /// seconds as seen by this client.
    Loss(Vec<(u64, f64)>, f64, f64),
    /// The worker executed and reported an application error — not
    /// retryable, surfaces as a named hard error.
    AppError(String),
}

/// One coordinator-side connection to a `lezo worker` process.
pub struct WorkerClient {
    addr: String,
    shard: usize,
    timeout: Duration,
    retries: u32,
    stream: Option<TcpStream>,
    alive: bool,
}

fn connect_stream(addr: &str, timeout: Duration, label: &str) -> Result<TcpStream> {
    let sock = addr
        .to_socket_addrs()
        .with_context(|| format!("{label}: cannot resolve worker address '{addr}'"))?
        .next()
        .ok_or_else(|| anyhow!("{label}: worker address '{addr}' resolves to nothing"))?;
    let stream = TcpStream::connect_timeout(&sock, timeout).with_context(|| {
        format!("{label}: cannot connect within {}ms", timeout.as_millis())
    })?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    Ok(stream)
}

impl WorkerClient {
    fn new(addr: &str, shard: usize, timeout_ms: u64, retries: u32) -> WorkerClient {
        WorkerClient {
            addr: addr.trim().to_string(),
            shard,
            timeout: Duration::from_millis(timeout_ms),
            retries,
            stream: None,
            alive: true,
        }
    }

    fn label(&self) -> String {
        format!("shard {} worker at {}", self.shard, self.addr)
    }

    /// One request/reply exchange under bounded reconnect-and-resend
    /// retries. Safe to retry because the worker serves a repeated `req_id`
    /// from its reply cache without re-executing. `HBEA` frames refresh the
    /// read deadline and are skipped. The reply's echoed `req_id` is
    /// verified and stripped.
    fn request(
        &mut self,
        tag: [u8; 4],
        req_id: u64,
        payload: &[u8],
        deadline: Option<Instant>,
    ) -> Result<([u8; 4], Vec<u8>)> {
        let label = self.label();
        let attempts = self.retries.max(1);
        crate::util::retry_with_backoff_deadline(&label, attempts, 10, deadline, || {
            let mut stream = match self.stream.take() {
                Some(s) => s,
                None => {
                    let mut s = connect_stream(&self.addr, self.timeout, &label)?;
                    write_hello(&mut s)?;
                    expect_hello(&mut s, &label)?;
                    s
                }
            };
            let r = (|| -> Result<([u8; 4], Vec<u8>)> {
                write_frame(&mut stream, &tag, payload)?;
                loop {
                    let (rtag, rbody) = read_frame(&mut stream, &label)?;
                    if rtag == T_HBEA {
                        continue;
                    }
                    let mut cur = Cur::new(&rbody, format!("{label}: '{}' reply", tag_name(&rtag)));
                    let got = cur.u64()?;
                    ensure!(
                        got == req_id,
                        "{label}: reply req id {got} does not match request {req_id} (stale frame)"
                    );
                    return Ok((rtag, rbody[8..].to_vec()));
                }
            })();
            match r {
                Ok(v) => {
                    self.stream = Some(stream);
                    Ok(v)
                }
                // drop the (possibly desynced) stream; the retry reconnects
                Err(e) => Err(e),
            }
        })
    }

    /// Total-deadline for control-plane requests (uploads, sweeps, pings):
    /// enough for every attempt to run its full socket timeout plus backoff.
    fn control_deadline(&self) -> Option<Instant> {
        let budget = self.timeout.saturating_mul(self.retries.max(1) + 1);
        Some(Instant::now() + budget + Duration::from_millis(500))
    }

    /// Send a request whose only success reply is `OKAY`.
    fn call_ok(&mut self, tag: [u8; 4], req_id: u64, payload: &[u8]) -> Result<()> {
        let (rtag, body) = self.request(tag, req_id, payload, self.control_deadline())?;
        if rtag == T_FAIL {
            bail!("{}: {}", self.label(), decode_fail_body(&body, &self.label())?);
        }
        ensure!(
            rtag == T_OKAY,
            "{}: unexpected reply '{}' to '{}'",
            self.label(),
            tag_name(&rtag),
            tag_name(&tag)
        );
        Ok(())
    }

    /// Dispatch a plan. No total deadline: each read is bounded by the
    /// socket timeout and kept alive by worker heartbeats, and attempts are
    /// bounded by `retries` — so this cannot hang, but a long forward under
    /// a healthy heartbeat is allowed to take as long as it takes.
    fn plan_request(&mut self, req_id: u64, payload: Vec<u8>) -> Result<PlanOutcome> {
        let t0 = Instant::now();
        let (rtag, body) = self.request(T_PLAN, req_id, &payload, None)?;
        let wall = t0.elapsed().as_secs_f64();
        if rtag == T_FAIL {
            return Ok(PlanOutcome::AppError(decode_fail_body(&body, &self.label())?));
        }
        ensure!(
            rtag == T_LOSS,
            "{}: unexpected reply '{}' to 'PLAN'",
            self.label(),
            tag_name(&rtag)
        );
        let mut cur = Cur::new(&body, format!("{}: LOSS reply", self.label()));
        let compute = cur.f64()?;
        let n = cur.u64()? as usize;
        ensure!(n <= 1 << 24, "{}: implausible loss count {n}", self.label());
        let mut pairs = Vec::with_capacity(n);
        for _ in 0..n {
            let idx = cur.u64()?;
            let loss = cur.f64()?;
            pairs.push((idx, loss));
        }
        cur.finish()?;
        Ok(PlanOutcome::Loss(pairs, compute, (wall - compute).max(0.0)))
    }
}

fn decode_fail_body(body: &[u8], label: &str) -> Result<String> {
    let mut cur = Cur::new(body, format!("{label}: FAIL reply"));
    let msg = cur.str_()?;
    cur.finish()?;
    Ok(msg)
}

/// The coordinator's set of remote workers: broadcast mutations, plan
/// fan-out with degraded-mode re-partitioning, liveness bookkeeping, and
/// round-trip-latency accounting.
pub struct RemotePool {
    workers: Vec<WorkerClient>,
    next_req: u64,
    rt_secs: f64,
}

impl RemotePool {
    /// Connect to and `INIT` every worker. Startup is strict — a worker
    /// that cannot be initialized is a named hard error, not a degraded
    /// start (degradation is for failures *mid-run*).
    pub fn connect(opts: &SocketOpts) -> Result<RemotePool> {
        ensure!(
            !opts.workers.is_empty(),
            "socket transport needs at least one worker address (set the `workers` config \
             key to a comma-separated list of host:port)"
        );
        let timeout_ms = resolve_net_timeout_ms(opts.timeout_ms)?;
        let retries = resolve_net_retries(opts.retries)?;
        let mut pool = RemotePool { workers: Vec::new(), next_req: 1, rt_secs: 0.0 };
        let n = opts.workers.len();
        for (i, addr) in opts.workers.iter().enumerate() {
            let mut w = WorkerClient::new(addr, i, timeout_ms, retries);
            let req = pool.fresh_req();
            let mut p = Vec::new();
            put_u64(&mut p, req);
            put_str(&mut p, &opts.model);
            put_str(&mut p, &opts.precision.to_string());
            put_str(&mut p, &opts.artifact_dir);
            put_str(&mut p, &opts.faults);
            put_u32(&mut p, i as u32);
            put_u32(&mut p, n as u32);
            w.call_ok(T_INIT, req, &p)
                .with_context(|| format!("initializing shard {i} worker at '{addr}'"))?;
            pool.workers.push(w);
        }
        Ok(pool)
    }

    fn fresh_req(&mut self) -> u64 {
        let r = self.next_req;
        self.next_req += 1;
        r
    }

    /// Workers configured at startup (the pool's shard count).
    pub fn total(&self) -> usize {
        self.workers.len()
    }

    /// Workers still considered alive.
    pub fn live(&self) -> usize {
        self.workers.iter().filter(|w| w.alive).count()
    }

    /// Drain the accumulated transport round-trip time (seconds).
    pub fn take_rt(&mut self) -> f64 {
        std::mem::take(&mut self.rt_secs)
    }

    fn mark_dead(&mut self, i: usize, why: &str) {
        if !self.workers[i].alive {
            return;
        }
        self.workers[i].alive = false;
        self.workers[i].stream = None;
        let live = self.live();
        // the degradation marker CI greps for — keep the wording stable
        crate::info!(
            "shard {} lost, continuing with {} shards ({})",
            self.workers[i].shard,
            live,
            why
        );
    }

    fn ensure_some_alive(&self, what: &str) -> Result<()> {
        ensure!(
            self.live() > 0,
            "all {} socket shard workers are dead ({what} cannot proceed); restart the \
             workers and resume from the last checkpoint",
            self.workers.len()
        );
        Ok(())
    }

    /// Broadcast one mutation to every live worker. A worker that fails
    /// after bounded retries is declared dead (it lost lockstep and can
    /// never rejoin this run); losing the *last* worker is a hard error.
    fn broadcast(&mut self, what: &str, tag: [u8; 4], body: &[u8]) -> Result<()> {
        for i in 0..self.workers.len() {
            if !self.workers[i].alive {
                continue;
            }
            let req = self.fresh_req();
            let mut p = Vec::with_capacity(8 + body.len());
            put_u64(&mut p, req);
            p.extend_from_slice(body);
            if let Err(e) = self.workers[i].call_ok(tag, req, &p) {
                self.mark_dead(i, &format!("{what} failed: {e:#}"));
            }
        }
        self.ensure_some_alive(what)
    }

    pub fn upload(&mut self, id: u64, data: &[f32]) -> Result<()> {
        let mut body = Vec::with_capacity(16 + data.len() * 4);
        put_u64(&mut body, id);
        put_f32s(&mut body, data);
        self.broadcast("parameter upload", T_UPLD, &body)
    }

    /// Best-effort free (never marks a worker dead over garbage collection).
    pub fn free(&mut self, ids: &[u64]) {
        for i in 0..self.workers.len() {
            if !self.workers[i].alive {
                continue;
            }
            let req = self.fresh_req();
            let mut p = Vec::new();
            put_u64(&mut p, req);
            put_u64s(&mut p, ids);
            if let Err(e) = self.workers[i].call_ok(T_FREE, req, &p) {
                self.mark_dead(i, &format!("buffer free failed: {e:#}"));
            }
        }
    }

    pub fn axpy_inplace(&mut self, id: u64, len: usize, seed: i32, coeff: f32) -> Result<()> {
        let mut body = Vec::new();
        put_u64(&mut body, id);
        put_u64(&mut body, len as u64);
        put_i32(&mut body, seed);
        put_f32(&mut body, coeff);
        self.broadcast("broadcast sweep", T_AXPY, &body)
    }

    #[allow(clippy::too_many_arguments)]
    pub fn axpy_masked_inplace(
        &mut self,
        id: u64,
        pref_id: u64,
        tau: f32,
        len: usize,
        seed: i32,
        coeff: f32,
    ) -> Result<()> {
        let mut body = Vec::new();
        put_u64(&mut body, id);
        put_u64(&mut body, pref_id);
        put_f32(&mut body, tau);
        put_u64(&mut body, len as u64);
        put_i32(&mut body, seed);
        put_f32(&mut body, coeff);
        self.broadcast("broadcast masked sweep", T_AXPM, &body)
    }

    pub fn axpy_alloc(&mut self, src: u64, dst: u64, len: usize, seed: i32, coeff: f32) -> Result<()> {
        let mut body = Vec::new();
        put_u64(&mut body, src);
        put_u64(&mut body, dst);
        put_u64(&mut body, len as u64);
        put_i32(&mut body, seed);
        put_f32(&mut body, coeff);
        self.broadcast("allocating sweep", T_AXPN, &body)
    }

    #[allow(clippy::too_many_arguments)]
    pub fn axpy_masked_alloc(
        &mut self,
        src: u64,
        pref: u64,
        dst: u64,
        tau: f32,
        len: usize,
        seed: i32,
        coeff: f32,
    ) -> Result<()> {
        let mut body = Vec::new();
        put_u64(&mut body, src);
        put_u64(&mut body, pref);
        put_u64(&mut body, dst);
        put_f32(&mut body, tau);
        put_u64(&mut body, len as u64);
        put_i32(&mut body, seed);
        put_f32(&mut body, coeff);
        self.broadcast("allocating masked sweep", T_AXMN, &body)
    }

    /// Explicit liveness probe of every worker (tests; also a cheap way to
    /// fail fast before dispatching a plan into a dead pool).
    pub fn ping_all(&mut self) -> Result<()> {
        for i in 0..self.workers.len() {
            if !self.workers[i].alive {
                continue;
            }
            let req = self.fresh_req();
            let mut p = Vec::new();
            put_u64(&mut p, req);
            let deadline = self.workers[i].control_deadline();
            match self.workers[i].request(T_PING, req, &p, deadline) {
                Ok((t, _)) if t == T_PONG => {}
                Ok((t, _)) => bail!(
                    "{}: unexpected reply '{}' to 'PING'",
                    self.workers[i].label(),
                    tag_name(&t)
                ),
                Err(e) => self.mark_dead(i, &format!("ping failed: {e:#}")),
            }
        }
        self.ensure_some_alive("heartbeat ping")
    }

    /// Ask every worker to exit (tests / orderly teardown). Never fails —
    /// a worker that is already gone is the desired end state.
    pub fn shutdown(&mut self) {
        for i in 0..self.workers.len() {
            if !self.workers[i].alive {
                continue;
            }
            let req = self.fresh_req();
            let mut p = Vec::new();
            put_u64(&mut p, req);
            let _ = self.workers[i].call_ok(T_SHUT, req, &p);
            self.workers[i].alive = false;
            self.workers[i].stream = None;
        }
    }

    /// Fan one plan out to the live workers and gather a complete
    /// `(eval idx -> loss)` cover, degrading on worker death.
    ///
    /// Round 1 sends the plan to **every** live worker (workers with no
    /// owned evals still walk the sweeps — that is what keeps them in
    /// lockstep). If workers die, the still-missing evals are re-partitioned
    /// over the survivors with the same [`crate::runtime::sharded::shard_owner`]
    /// round-robin rule, each chosen survivor is first **resynced** to the
    /// coordinator's pre-plan `snapshot` of the touched units (a survivor
    /// has already walked the plan once and sits at the post-plan bits; the
    /// f32 perturb/restore roundtrip is not a bitwise identity, so replaying
    /// from the snapshot is what makes the re-run reproduce every eval
    /// bit-exactly), and the plan is re-sent with only the missing evals.
    /// The loop continues until the cover is complete or no workers remain.
    pub fn run_plan(
        &mut self,
        plan: &StepPlan,
        unit_ids: &[u64],
        base_ids: &[u64],
        peft: PeftMode,
        batch: &Batch,
        snapshot: &[(u64, Vec<f32>)],
    ) -> Result<Vec<f64>> {
        let n_evals = plan.evals.len();
        // shared request body: everything between req_id and the eval list
        let mut mid = Vec::new();
        put_str(&mut mid, &peft.to_string());
        put_u64s(&mut mid, unit_ids);
        put_u64s(&mut mid, base_ids);
        encode_batch_into(&mut mid, batch);
        mid.extend_from_slice(&encode_plan(plan));

        let mut got: Vec<Option<f64>> = vec![None; n_evals];
        let mut first_round = true;
        loop {
            let live_idx: Vec<usize> = self
                .workers
                .iter()
                .enumerate()
                .filter(|(_, w)| w.alive)
                .map(|(i, _)| i)
                .collect();
            ensure!(
                !live_idx.is_empty(),
                "all {} socket shard workers are dead at step {} — restart the workers and \
                 resume from the last checkpoint",
                self.workers.len(),
                plan.step + 1
            );
            // assign every still-missing eval round-robin over the live
            // ranks — the same shard_owner rule as thread mode applied to
            // the surviving set, so degradation is elastic re-sharding
            let mut assign: Vec<Vec<usize>> = vec![Vec::new(); self.workers.len()];
            for e in 0..n_evals {
                if got[e].is_some() {
                    continue;
                }
                let rank = crate::runtime::sharded::shard_owner(e, live_idx.len())?;
                assign[live_idx[rank]].push(e);
            }
            let participants: Vec<usize> = if first_round {
                live_idx.clone()
            } else {
                live_idx.iter().copied().filter(|&i| !assign[i].is_empty()).collect()
            };
            if !first_round {
                for &i in &participants {
                    for (id, data) in snapshot {
                        let req = self.fresh_req();
                        let mut p = Vec::with_capacity(24 + data.len() * 4);
                        put_u64(&mut p, req);
                        put_u64(&mut p, *id);
                        put_f32s(&mut p, data);
                        if let Err(e) = self.workers[i].call_ok(T_UPLD, req, &p) {
                            self.mark_dead(i, &format!("pre-redispatch resync failed: {e:#}"));
                            break;
                        }
                    }
                }
            }
            // preassigned req ids + payloads, then parallel dispatch: each
            // scoped thread owns a disjoint &mut WorkerClient
            let mut jobs: HashMap<usize, (u64, Vec<u8>)> = HashMap::new();
            for &i in &participants {
                if !self.workers[i].alive {
                    continue;
                }
                let req = self.fresh_req();
                let mut p = Vec::with_capacity(16 + mid.len() + assign[i].len() * 8);
                put_u64(&mut p, req);
                p.extend_from_slice(&mid);
                put_u64(&mut p, assign[i].len() as u64);
                for &e in &assign[i] {
                    put_u64(&mut p, e as u64);
                }
                jobs.insert(i, (req, p));
            }
            let results: Vec<(usize, Result<PlanOutcome>)> = std::thread::scope(|s| {
                let handles: Vec<_> = self
                    .workers
                    .iter_mut()
                    .enumerate()
                    .filter_map(|(i, w)| {
                        jobs.remove(&i).map(|(req, p)| s.spawn(move || (i, w.plan_request(req, p))))
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| {
                        h.join()
                            .unwrap_or_else(|_| (usize::MAX, Err(anyhow!("plan dispatch thread panicked"))))
                    })
                    .collect()
            });

            let mut round_rt: f64 = 0.0;
            let mut any_dead = false;
            for (i, r) in results {
                ensure!(i != usize::MAX, "plan dispatch thread panicked");
                match r {
                    Ok(PlanOutcome::Loss(pairs, _compute, rt)) => {
                        round_rt = round_rt.max(rt);
                        for (idx, loss) in pairs {
                            let idx = idx as usize;
                            ensure!(
                                idx < n_evals,
                                "{}: returned out-of-range eval index {idx}",
                                self.workers[i].label()
                            );
                            got[idx] = Some(loss);
                        }
                    }
                    Ok(PlanOutcome::AppError(msg)) => {
                        bail!("{}: {msg}", self.workers[i].label())
                    }
                    Err(e) => {
                        self.mark_dead(i, &format!("plan dispatch failed: {e:#}"));
                        any_dead = true;
                    }
                }
            }
            self.rt_secs += round_rt;
            if got.iter().all(|g| g.is_some()) {
                return Ok(got.into_iter().map(|g| g.unwrap()).collect());
            }
            // a live worker silently skipping an owned eval is a protocol
            // bug, not a fault to degrade around
            ensure!(any_dead, "sharded socket gather is missing an eval result");
            first_round = false;
        }
    }
}

// ---------------------------------------------------------------------------
// worker side: `lezo worker --listen <addr>`
// ---------------------------------------------------------------------------

enum NetAction {
    Send,
    /// net-drop: the reply is cached but never sent; close the connection.
    DropConn,
    /// net-corrupt: send the reply with a flipped CRC byte, then close.
    CorruptCrc,
}

struct WorkerState {
    shard: usize,
    shards: usize,
    backend: Option<NativeBackend>,
    bufs: HashMap<u64, NativeBuf>,
    faults: crate::coordinator::faults::FaultPlan,
    /// Once-only latches for injected faults (kind, 1-based step).
    fired: HashSet<(&'static str, u64)>,
    /// The idempotency cache: last `(req_id, reply tag, reply payload)`.
    /// A retried request with the same id is served from here — executed
    /// work is never executed twice.
    last_reply: Option<(u64, [u8; 4], Vec<u8>)>,
}

fn parse_disp<T: std::str::FromStr>(s: &str) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    s.parse::<T>().map_err(|e| anyhow!("{e}"))
}

fn build_worker_backend(model: &str, precision: Precision, artifact_dir: &str) -> Result<NativeBackend> {
    let backend = if artifact_dir.is_empty() {
        NativeBackend::preset(model)?.with_precision(precision)
    } else {
        // mirror the trainer's native replica construction so worker bits
        // match the coordinator's local replica exactly
        let dir = std::path::Path::new(artifact_dir);
        let (spec, manifest) = crate::runtime::backend::resolve_model(model, dir)?;
        let b = NativeBackend::new(spec)?.with_precision(precision);
        match manifest {
            Some(m) => b.with_artifacts(m)?,
            None => b.with_checkpoint_dir(dir),
        }
    };
    ensure!(
        backend.supports_precision(precision),
        "worker backend does not support precision {precision}"
    );
    Ok(backend)
}

/// Run a worker process: bind, announce the bound address on stdout
/// (`worker listening on <addr>` — spawners parse this line), then serve
/// coordinator connections one at a time until `SHUT` or the process is
/// killed. State (replica, buffers, fault latches) lives in the process
/// and survives reconnects; a fresh `INIT` resets it, so one worker can
/// serve several runs in sequence (e.g. crash-then-resume tests).
pub fn run_worker(listen: &str) -> Result<()> {
    let listener = TcpListener::bind(listen)
        .with_context(|| format!("worker cannot listen on '{listen}'"))?;
    let addr = listener.local_addr()?;
    println!("worker listening on {addr}");
    std::io::stdout().flush().ok();
    let mut state = WorkerState {
        shard: 0,
        shards: 0,
        backend: None,
        bufs: HashMap::new(),
        faults: crate::coordinator::faults::FaultPlan::parse("")?,
        fired: HashSet::new(),
        last_reply: None,
    };
    loop {
        let (stream, peer) = match listener.accept() {
            Ok(v) => v,
            Err(e) => {
                crate::info!("worker at {addr}: accept failed: {e}");
                continue;
            }
        };
        match serve_conn(stream, &mut state) {
            Ok(true) => {
                crate::info!("worker at {addr}: shutdown requested");
                return Ok(());
            }
            Ok(false) => {}
            Err(e) => crate::info!("worker at {addr}: connection from {peer} ended: {e:#}"),
        }
    }
}

/// Serve one coordinator connection; `Ok(true)` means `SHUT` was received.
fn serve_conn(mut stream: TcpStream, state: &mut WorkerState) -> Result<bool> {
    stream.set_nodelay(true).ok();
    // generous: a coordinator that goes silent this long is gone, and the
    // worker must fall back to accept() rather than block forever
    stream.set_read_timeout(Some(Duration::from_secs(300)))?;
    stream.set_write_timeout(Some(Duration::from_secs(30)))?;
    write_hello(&mut stream)?;
    expect_hello(&mut stream, "worker handshake")?;
    loop {
        let (tag, payload) = match read_frame_opt(&mut stream, "worker rx")? {
            Some(f) => f,
            None => return Ok(false), // coordinator closed cleanly
        };
        let mut cur = Cur::new(&payload, format!("worker rx '{}'", tag_name(&tag)));
        let req_id = cur.u64()?;
        if let Some((cached_id, rtag, rbody)) = &state.last_reply {
            if *cached_id == req_id {
                // a retried request: the original already executed — serve
                // the cached reply, never execute twice
                let (rtag, rbody) = (*rtag, rbody.clone());
                write_frame(&mut stream, &rtag, &rbody)?;
                continue;
            }
        }
        if tag == T_SHUT {
            let mut body = Vec::new();
            put_u64(&mut body, req_id);
            write_frame(&mut stream, &T_OKAY, &body)?;
            return Ok(true);
        }
        let (rtag, rbody, action) = match handle_request(state, &tag, &mut cur, req_id, &stream) {
            Ok(v) => v,
            Err(e) => {
                let mut body = Vec::new();
                put_u64(&mut body, req_id);
                put_str(&mut body, &format!("{e:#}"));
                (T_FAIL, body, NetAction::Send)
            }
        };
        // cache the CLEAN reply before any injected reply-path fault, so
        // the coordinator's retry always recovers the true result
        state.last_reply = Some((req_id, rtag, rbody.clone()));
        match action {
            NetAction::Send => write_frame(&mut stream, &rtag, &rbody)?,
            NetAction::DropConn => {
                crate::info!("worker shard {}: injected net-drop — closing without reply", state.shard);
                return Ok(false);
            }
            NetAction::CorruptCrc => {
                crate::info!("worker shard {}: injected net-corrupt — sending a torn frame", state.shard);
                let mut frame = frame_bytes(&rtag, &rbody);
                let n = frame.len();
                frame[n - 1] ^= 0xFF; // flip a CRC byte: the receiver must reject
                stream.write_all(&frame).ok();
                return Ok(false);
            }
        }
    }
}

fn handle_request(
    state: &mut WorkerState,
    tag: &[u8; 4],
    cur: &mut Cur,
    req_id: u64,
    stream: &TcpStream,
) -> Result<([u8; 4], Vec<u8>, NetAction)> {
    use crate::runtime::sharded::{resolve_shared, resolve_shared_mut};
    let mut ok = Vec::new();
    put_u64(&mut ok, req_id);
    match *tag {
        T_PING => Ok((T_PONG, ok, NetAction::Send)),
        T_INIT => {
            let model = cur.str_()?;
            let precision: Precision = parse_disp(&cur.str_()?)?;
            let artifact_dir = cur.str_()?;
            let faults = cur.str_()?;
            let shard = cur.u32()? as usize;
            let shards = cur.u32()? as usize;
            let backend = build_worker_backend(&model, precision, &artifact_dir)?;
            state.backend = Some(backend);
            state.bufs.clear();
            state.faults = crate::coordinator::faults::FaultPlan::parse(&faults)?;
            state.fired.clear();
            state.last_reply = None;
            state.shard = shard;
            state.shards = shards;
            crate::info!("worker: initialized as shard {shard}/{shards} for model '{model}' ({precision})");
            Ok((T_OKAY, ok, NetAction::Send))
        }
        T_UPLD => {
            let id = cur.u64()?;
            let data = cur.f32s()?;
            let backend =
                state.backend.as_ref().ok_or_else(|| anyhow!("worker received UPLD before INIT"))?;
            let buf = backend.upload(&data)?;
            state.bufs.insert(id, buf);
            Ok((T_OKAY, ok, NetAction::Send))
        }
        T_FREE => {
            for id in cur.u64s()? {
                state.bufs.remove(&id);
            }
            Ok((T_OKAY, ok, NetAction::Send))
        }
        T_AXPY => {
            let id = cur.u64()?;
            let len = cur.u64()? as usize;
            let seed = cur.i32()?;
            let coeff = cur.f32()?;
            let WorkerState { backend, bufs, .. } = state;
            let backend = backend.as_ref().ok_or_else(|| anyhow!("worker received AXPY before INIT"))?;
            backend.zo_axpy_inplace(resolve_shared_mut(bufs, id)?, len, seed, coeff)?;
            Ok((T_OKAY, ok, NetAction::Send))
        }
        T_AXPM => {
            let id = cur.u64()?;
            let pid = cur.u64()?;
            let tau = cur.f32()?;
            let len = cur.u64()? as usize;
            let seed = cur.i32()?;
            let coeff = cur.f32()?;
            let WorkerState { backend, bufs, .. } = state;
            let backend = backend.as_ref().ok_or_else(|| anyhow!("worker received AXPM before INIT"))?;
            // two ids into one map: copy the preference buffer around the &mut
            let pref_copy = resolve_shared(bufs, pid)?.data().to_vec();
            let pref_buf = NativeBuf::from(pref_copy);
            backend.zo_axpy_masked_inplace(resolve_shared_mut(bufs, id)?, &pref_buf, tau, len, seed, coeff)?;
            Ok((T_OKAY, ok, NetAction::Send))
        }
        T_AXPN => {
            let src = cur.u64()?;
            let dst = cur.u64()?;
            let len = cur.u64()? as usize;
            let seed = cur.i32()?;
            let coeff = cur.f32()?;
            let WorkerState { backend, bufs, .. } = state;
            let backend = backend.as_ref().ok_or_else(|| anyhow!("worker received AXPN before INIT"))?;
            let out = backend.zo_axpy(resolve_shared(bufs, src)?, len, seed, coeff)?;
            bufs.insert(dst, out);
            Ok((T_OKAY, ok, NetAction::Send))
        }
        T_AXMN => {
            let src = cur.u64()?;
            let pref = cur.u64()?;
            let dst = cur.u64()?;
            let tau = cur.f32()?;
            let len = cur.u64()? as usize;
            let seed = cur.i32()?;
            let coeff = cur.f32()?;
            let WorkerState { backend, bufs, .. } = state;
            let backend = backend.as_ref().ok_or_else(|| anyhow!("worker received AXMN before INIT"))?;
            let (u, p) = (resolve_shared(bufs, src)?, resolve_shared(bufs, pref)?);
            let out = backend.zo_axpy_masked(u, p, tau, len, seed, coeff)?;
            bufs.insert(dst, out);
            Ok((T_OKAY, ok, NetAction::Send))
        }
        T_PLAN => handle_plan(state, cur, req_id, stream),
        _ => bail!("unknown request tag '{}'", tag_name(tag)),
    }
}

fn handle_plan(
    state: &mut WorkerState,
    cur: &mut Cur,
    req_id: u64,
    stream: &TcpStream,
) -> Result<([u8; 4], Vec<u8>, NetAction)> {
    let peft: PeftMode = parse_disp(&cur.str_()?)?;
    let unit_ids = cur.u64s()?;
    let base_ids = cur.u64s()?;
    let batch = decode_batch(cur)?;
    let plan = decode_plan(cur)?;
    let n = cur.u64()? as usize;
    ensure!(n <= 1 << 24, "implausible owned-eval count {n}");
    let mut owned = BTreeSet::new();
    for _ in 0..n {
        owned.insert(cur.u64()? as usize);
    }
    let s1 = plan.step + 1; // the faults grammar is 1-based

    // worker-crash@K:shard — die at plan receipt, before any work
    if state.faults.worker_crash_at(s1, state.shard) && state.fired.insert(("worker-crash", s1)) {
        eprintln!("[lezo] worker shard {}: injected worker-crash at step {s1} — exiting", state.shard);
        std::process::exit(3);
    }
    // net-delay@K:ms — stall before compute and before heartbeats start,
    // so a delay longer than the coordinator timeout looks like a dead peer
    if let Some(ms) = state.faults.net_delay_at(s1) {
        if state.fired.insert(("net-delay", s1)) {
            std::thread::sleep(Duration::from_millis(ms));
        }
    }

    let WorkerState { backend, bufs, .. } = state;
    let backend = backend.as_ref().ok_or_else(|| anyhow!("worker received PLAN before INIT"))?;

    // compute under a heartbeat: HBEA frames every ~200ms keep the
    // coordinator's read timeout from declaring us dead during long evals
    let sw = crate::util::Stopwatch::start();
    let done = AtomicBool::new(false);
    let hb_stream = stream.try_clone().ok();
    let gathered = std::thread::scope(|s| {
        if let Some(mut hb) = hb_stream {
            let done = &done;
            s.spawn(move || loop {
                for _ in 0..HEARTBEAT_EVERY_TICKS {
                    if done.load(Ordering::Relaxed) {
                        return;
                    }
                    std::thread::sleep(Duration::from_millis(HEARTBEAT_TICK_MS));
                }
                if write_frame(&mut hb, &T_HBEA, &[]).is_err() {
                    return;
                }
            });
        }
        let r = crate::runtime::sharded::run_plan_on_replica(
            backend, bufs, &plan, &unit_ids, &base_ids, peft, &batch, &owned,
        );
        done.store(true, Ordering::Relaxed);
        r
    });
    let gathered = gathered?;

    let mut body = Vec::with_capacity(24 + gathered.len() * 16);
    put_u64(&mut body, req_id);
    put_f64(&mut body, sw.secs());
    put_u64(&mut body, gathered.len() as u64);
    for (idx, loss) in &gathered {
        put_u64(&mut body, *idx as u64);
        put_f64(&mut body, *loss);
    }
    // reply-path faults, each injected exactly once
    let action = if state.faults.net_drop_at(s1) && state.fired.insert(("net-drop", s1)) {
        NetAction::DropConn
    } else if state.faults.net_corrupt_at(s1) && state.fired.insert(("net-corrupt", s1)) {
        NetAction::CorruptCrc
    } else {
        NetAction::Send
    };
    Ok((T_LOSS, body, action))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::optim::ProbeSchedule;

    fn sample_plan() -> StepPlan {
        StepPlan {
            step: 41,
            schedule: ProbeSchedule::TwoSided,
            phases: vec![
                PlanPhase::Sweep(vec![
                    SweepOp { unit: 0, len: 8, seed: 123, coeff: 1.0e-3 },
                    SweepOp { unit: 2, len: 16, seed: -7, coeff: -2.0e-3 },
                ]),
                PlanPhase::Eval { idx: 0 },
                PlanPhase::Sweep(vec![SweepOp { unit: 0, len: 8, seed: 123, coeff: -2.0e-3 }]),
                PlanPhase::Eval { idx: 1 },
            ],
            evals: vec![EvalSpec { probe: 0 }, EvalSpec { probe: 1 }],
            recovery: vec![
                vec![SweepOp { unit: 0, len: 8, seed: 123, coeff: -1.0e-3 }],
                vec![],
            ],
        }
    }

    #[test]
    fn crc32_matches_known_answers() {
        // the IEEE check value, same as the checkpoint envelope's CRC
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn frame_round_trips() {
        let payload = b"hello transport".to_vec();
        let bytes = frame_bytes(&T_PLAN, &payload);
        let (tag, got) = decode_frame(&bytes, "test").unwrap();
        assert_eq!(tag, T_PLAN);
        assert_eq!(got, payload);
    }

    #[test]
    fn plan_codec_round_trips() {
        let plan = sample_plan();
        let bytes = encode_plan(&plan);
        let mut cur = Cur::new(&bytes, "plan");
        let got = decode_plan(&mut cur).unwrap();
        cur.finish().unwrap();
        assert_eq!(got, plan);
        // and the encoding is deterministic
        assert_eq!(encode_plan(&got), bytes);
    }

    #[test]
    fn batch_codec_round_trips() {
        let seqs: Vec<Vec<u32>> = (0..3).map(|r| (0..6u32).map(|i| 10 + r + i).collect()).collect();
        let batch = Batch::lm_batch(&seqs, 3, 8).unwrap();
        let mut bytes = Vec::new();
        encode_batch_into(&mut bytes, &batch);
        let mut cur = Cur::new(&bytes, "batch");
        let got = decode_batch(&mut cur).unwrap();
        cur.finish().unwrap();
        assert_eq!(got, batch);
    }

    #[test]
    fn cursor_truncation_names_the_offset() {
        let bytes = [1u8, 2, 3];
        let mut cur = Cur::new(&bytes, "toy");
        cur.take(2).unwrap();
        let err = cur.u64().unwrap_err().to_string();
        assert!(err.contains("toy") && err.contains("byte offset 2"), "{err}");
    }

    #[test]
    fn net_env_knobs_are_strict() {
        // zero is rejected whichever side it comes from; skip quietly if an
        // ambient env override is present (it would win over the argument)
        if std::env::var("LEZO_NET_TIMEOUT_MS").unwrap_or_default().is_empty() {
            let e = resolve_net_timeout_ms(0).unwrap_err().to_string();
            assert!(e.contains("net_timeout_ms") && e.contains("LEZO_NET_TIMEOUT_MS"), "{e}");
        }
        if std::env::var("LEZO_NET_RETRIES").unwrap_or_default().is_empty() {
            let e = resolve_net_retries(0).unwrap_err().to_string();
            assert!(e.contains("net_retries") && e.contains("LEZO_NET_RETRIES"), "{e}");
        }
    }

    #[test]
    fn fail_body_round_trips() {
        let mut body = Vec::new();
        put_str(&mut body, "backend exploded");
        assert_eq!(decode_fail_body(&body, "t").unwrap(), "backend exploded");
    }
}

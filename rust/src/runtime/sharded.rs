//! ShardedBackend: N in-process native worker replicas executing one run in
//! lockstep — the seed-parallel data path the LeZO/MeZO invariant makes
//! possible.
//!
//! Because every perturbation is *regenerated* from its `(step, probe,
//! unit)` seed inside the zo_axpy kernel, a ZO step is fully described by a
//! [`StepPlan`]'s scalars. Each replica holds a full copy of the
//! parameters and applies every seeded sweep of the plan locally; only the
//! plan's forward *evaluations* are partitioned across replicas
//! ([`shard_owner`]), and only `(eval index, loss)` f64 scalars are
//! gathered back. Replicas never exchange parameters or gradients — they
//! stay bit-identical by construction, which is what the differential
//! harness (`rust/tests/backend_comparison.rs`) pins: `backend=sharded` at
//! any shard count must agree `to_bits`-exactly with `backend=native`.
//!
//! ## Lockstep rules
//!
//! - Every parameter mutation outside a plan (`zo_axpy_inplace` from
//!   `apply_coeffs`, the masked Sparse-MeZO sweeps, checkpoint re-uploads)
//!   is **broadcast** to all replicas.
//! - Inside [`Backend::run_zo_plan`] every worker applies **all** sweep
//!   phases in plan order and evaluates only the evals it owns
//!   (`idx % shards == worker`).
//! - Reads (`download`, the eval/predict forwards) go to replica 0.
//!
//! ## Threads
//!
//! Workers run on scoped threads for the duration of one plan. The run's
//! thread budget ([`crate::runtime::native::parallel::effective_threads`]
//! on the coordinator thread) is split across workers
//! ([`shard_thread_budget`]) via a per-worker scoped
//! [`parallel::with_threads`] override — the per-*thread* override cannot
//! leak between workers. A `LEZO_THREADS` env override still wins on every
//! thread by design (it outranks scoped overrides), so setting it under
//! `backend=sharded` oversubscribes rather than splits; results are
//! bit-identical either way because the native kernels are thread-count
//! invariant.
//!
//! ## Shard count (`shards` config key, `LEZO_SHARDS` env)
//!
//! The env override wins, mirroring `LEZO_THREADS`/`LEZO_PRECISION`:
//! unset/empty means "no override", anything else must parse as a positive
//! count — an unparseable value is a hard error naming the variable, never
//! a silent fall-through ([`env_shards`]).
//!
//! ## Transport (`shard_transport=thread|socket`)
//!
//! `thread` (default) is the in-process mode above. `socket`
//! ([`ShardedBackend::connect_socket`]) replaces the N in-process replicas
//! with one **local** replica plus a pool of remote `lezo worker --listen
//! <addr>` processes speaking the framed protocol in
//! [`crate::runtime::transport`]: mutations are broadcast to the pool,
//! plan evals are dispatched to the workers, and reads stay on the local
//! replica. Worker death mid-run degrades — the remaining evals are
//! re-partitioned over survivors via the same [`shard_owner`] rule, which
//! keeps the trajectory bit-identical to native by construction (the
//! partitioning only decides *where* an eval runs, never *what* it
//! computes). See the transport module docs for the failure model.

use crate::coordinator::metrics::{StageTimer, StageTimes};
use crate::data::batch::Batch;
use crate::model::spec::ModelSpec;
use crate::peft::PeftMode;
use crate::runtime::backend::{Backend, Precision};
use crate::runtime::native::{parallel, NativeBackend, NativeBuf};
use crate::runtime::plan::{PlanPhase, PlanResult, StepPlan};
use anyhow::{anyhow, ensure, Result};
use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Which shard owns work item `item` out of `shards` total — the single
/// partitioning rule (plan evals today; anything partitioned later must
/// route through here so the disjoint-cover property test covers it).
/// `shards = 0` is a hard error, not a modulo panic.
pub fn shard_owner(item: usize, shards: usize) -> Result<usize> {
    ensure!(shards >= 1, "shard partitioning needs >= 1 shard (got 0)");
    Ok(item % shards)
}

/// Worker `w`'s slice of a `total` thread budget split across `shards`
/// workers: near-equal shares, never below 1.
pub fn shard_thread_budget(total: usize, shards: usize, w: usize) -> usize {
    debug_assert!(shards >= 1 && w < shards);
    (total / shards + usize::from(w < total % shards)).max(1)
}

/// Parse a `LEZO_SHARDS` value: empty/unset means "no override", anything
/// else must be a positive integer — an unparseable or zero value is a
/// hard error naming the variable (the `LEZO_THREADS` strictness rule).
fn parse_shards(v: &str) -> Result<Option<usize>> {
    if v.is_empty() {
        return Ok(None);
    }
    match v.parse::<usize>() {
        Ok(n) if n > 0 => Ok(Some(n)),
        _ => Err(anyhow!(
            "LEZO_SHARDS='{v}' is not a valid shard count for the `shards` config key \
             (expected an integer >= 1; unset LEZO_SHARDS to use the config value)"
        )),
    }
}

/// `LEZO_SHARDS`: the env override for the `shards` config key.
pub fn env_shards() -> Result<Option<usize>> {
    parse_shards(&std::env::var("LEZO_SHARDS").unwrap_or_default())
}

/// Resolve the shard count for a run: `LEZO_SHARDS` wins, else the config
/// key's value; zero is rejected either way.
pub fn resolve_shards(requested: usize) -> Result<usize> {
    let n = env_shards()?.unwrap_or(requested);
    ensure!(
        n >= 1,
        "shards must be a positive count (got {n}; set the `shards` config key or \
         LEZO_SHARDS to an integer >= 1)"
    );
    Ok(n)
}

/// One worker: a full native backend plus its private copies of every live
/// buffer, keyed by the shared handle id.
struct Replica {
    backend: NativeBackend,
    bufs: HashMap<u64, NativeBuf>,
}

/// The sharded buffer handle: an id naming one logical buffer whose N
/// physical copies live inside the replicas. Dropping the handle queues
/// the id for garbage collection on the next backend entry.
pub struct ShardBuf {
    id: u64,
    len: usize,
    freed: Arc<Mutex<Vec<u64>>>,
}

impl Drop for ShardBuf {
    fn drop(&mut self) {
        if let Ok(mut freed) = self.freed.lock() {
            freed.push(self.id);
        }
    }
}

impl std::fmt::Debug for ShardBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ShardBuf(id {}, len {})", self.id, self.len)
    }
}

pub struct ShardedBackend {
    spec: ModelSpec,
    precision: Precision,
    replicas: RefCell<Vec<Replica>>,
    next_id: Cell<u64>,
    /// Ids of dropped [`ShardBuf`]s, reclaimed from every replica on the
    /// next backend entry (handles drop on the coordinator thread while no
    /// plan is in flight, so a lazy sweep is enough).
    freed: Arc<Mutex<Vec<u64>>>,
    /// `shard_transport=socket`: the pool of remote `lezo worker`
    /// processes. When set, `replicas` holds exactly one **local** replica
    /// (reads and FO stay on it, and it walks every sweep so coordinator
    /// bits match native); every mutation is additionally broadcast to the
    /// pool, and plan evals are dispatched to the workers.
    remote: Option<RefCell<crate::runtime::transport::RemotePool>>,
}

impl ShardedBackend {
    /// Build from pre-configured replicas (this is how the trainer applies
    /// precision/artifact adoption uniformly: configure one native backend
    /// per shard, hand them over). All replicas must agree on architecture
    /// and precision — a mismatch would silently break lockstep.
    pub fn from_replicas(replicas: Vec<NativeBackend>) -> Result<ShardedBackend> {
        ensure!(!replicas.is_empty(), "sharded backend needs >= 1 replica");
        let spec = replicas[0].spec().clone();
        let precision = replicas[0].precision();
        for r in &replicas[1..] {
            ensure!(
                r.spec().name == spec.name && r.precision() == precision,
                "sharded replicas must agree on model and precision \
                 ({}/{} vs {}/{})",
                spec.name,
                precision,
                r.spec().name,
                r.precision(),
            );
        }
        Ok(ShardedBackend {
            spec,
            precision,
            replicas: RefCell::new(
                replicas
                    .into_iter()
                    .map(|backend| Replica { backend, bufs: HashMap::new() })
                    .collect(),
            ),
            next_id: Cell::new(0),
            freed: Arc::new(Mutex::new(Vec::new())),
            remote: None,
        })
    }

    /// Socket transport: one local replica plus a pool of remote `lezo
    /// worker` processes (one per address in `opts.workers`), each
    /// initialized to the identical model/precision so the whole set runs
    /// in lockstep. Worker death mid-run degrades (see `run_zo_plan`);
    /// failure to *initialize* a worker is a hard error.
    pub fn connect_socket(
        replica: NativeBackend,
        opts: &crate::runtime::transport::SocketOpts,
    ) -> Result<ShardedBackend> {
        let pool = crate::runtime::transport::RemotePool::connect(opts)?;
        let mut backend = ShardedBackend::from_replicas(vec![replica])?;
        backend.remote = Some(RefCell::new(pool));
        Ok(backend)
    }

    /// `"socket"` when a remote pool is attached, else `"thread"`.
    pub fn transport(&self) -> &'static str {
        if self.remote.is_some() {
            "socket"
        } else {
            "thread"
        }
    }

    /// Run the broadcast mirror against the remote pool, if any.
    fn remote_mirror(
        &self,
        f: impl FnOnce(&mut crate::runtime::transport::RemotePool) -> Result<()>,
    ) -> Result<()> {
        match &self.remote {
            Some(pool) => f(&mut pool.borrow_mut()),
            None => Ok(()),
        }
    }

    /// `shards` plain replicas of an in-crate preset (tests, bench).
    pub fn preset(name: &str, shards: usize) -> Result<ShardedBackend> {
        ensure!(shards >= 1, "shards must be a positive count (got {shards})");
        let replicas = (0..shards)
            .map(|_| NativeBackend::preset(name))
            .collect::<Result<Vec<_>>>()?;
        ShardedBackend::from_replicas(replicas)
    }

    /// Preset replicas at a forward precision (bench's bf16 rows).
    pub fn preset_with_precision(
        name: &str,
        shards: usize,
        precision: Precision,
    ) -> Result<ShardedBackend> {
        ensure!(shards >= 1, "shards must be a positive count (got {shards})");
        let replicas = (0..shards)
            .map(|_| NativeBackend::preset(name).map(|b| b.with_precision(precision)))
            .collect::<Result<Vec<_>>>()?;
        ShardedBackend::from_replicas(replicas)
    }

    /// The shard count evals are partitioned over: the remote worker count
    /// in socket mode, else the in-process replica count.
    pub fn shards(&self) -> usize {
        match &self.remote {
            Some(pool) => pool.borrow().total(),
            None => self.replicas.borrow().len(),
        }
    }

    /// Drain the freed-id queue and drop those buffers from every replica.
    fn gc(&self) {
        let ids: Vec<u64> = match self.freed.lock() {
            Ok(mut freed) => freed.drain(..).collect(),
            Err(_) => return,
        };
        if ids.is_empty() {
            return;
        }
        let mut replicas = self.replicas.borrow_mut();
        for rep in replicas.iter_mut() {
            for id in &ids {
                rep.bufs.remove(id);
            }
        }
        drop(replicas);
        if let Some(pool) = &self.remote {
            pool.borrow_mut().free(&ids); // best-effort
        }
    }

    fn fresh_id(&self) -> u64 {
        let id = self.next_id.get();
        self.next_id.set(id + 1);
        id
    }

    fn handle(&self, id: u64, len: usize) -> ShardBuf {
        ShardBuf { id, len, freed: Arc::clone(&self.freed) }
    }

    /// Run `f` once per replica (broadcast mutation — the lockstep rule).
    fn each_replica(
        &self,
        mut f: impl FnMut(&NativeBackend, &mut HashMap<u64, NativeBuf>) -> Result<()>,
    ) -> Result<()> {
        let mut replicas = self.replicas.borrow_mut();
        for rep in replicas.iter_mut() {
            f(&rep.backend, &mut rep.bufs)?;
        }
        Ok(())
    }

    /// Socket-mode plan execution: the local replica walks every sweep
    /// phase (evals excluded — it exists so coordinator reads stay
    /// bit-identical to native), the remote pool runs the plan and gathers
    /// the `(eval idx, loss)` cover, degrading over worker death (see
    /// [`crate::runtime::transport::RemotePool::run_plan`]). Abort
    /// semantics mirror thread mode, with one extra move: after the local
    /// rollback-replay, the recovered bits are re-uploaded to every live
    /// worker so the pool re-enters lockstep.
    #[allow(clippy::too_many_arguments)]
    fn run_zo_plan_socket(
        &self,
        pool: &RefCell<crate::runtime::transport::RemotePool>,
        plan: &StepPlan,
        bufs: &mut [ShardBuf],
        peft: PeftMode,
        base: Option<&[ShardBuf]>,
        batch: &Batch,
        inject: &mut dyn FnMut(usize) -> Result<Option<f32>>,
        times: &mut StageTimes,
    ) -> Result<PlanResult> {
        let unit_ids: Vec<u64> = bufs.iter().map(|b| b.id).collect();
        let base_ids: Vec<u64> =
            base.map(|bs| bs.iter().map(|b| b.id).collect()).unwrap_or_default();
        let mut replicas = self.replicas.borrow_mut();
        let mut t = StageTimer::start();

        // pre-plan snapshot of the touched units: abort rollback here, and
        // the pre-redispatch resync of surviving workers in the pool
        let touched = plan.touched_units();
        let snapshot: Vec<(u64, Vec<f32>)> = touched
            .iter()
            .map(|&k| {
                let id = unit_ids[k];
                Ok((id, resolve(&replicas[0].bufs, id)?.data().to_vec()))
            })
            .collect::<Result<_>>()?;

        // local replica: every sweep phase in plan order, no evals — the
        // f32 perturb/restore roundtrip is not a bitwise identity, so
        // skipping the "net-zero" sweeps would desync it from the workers
        {
            let Replica { backend, bufs: rb } = &mut replicas[0];
            for phase in &plan.phases {
                if let PlanPhase::Sweep(ops) = phase {
                    for op in ops {
                        let buf = resolve_mut(rb, unit_ids[op.unit])?;
                        backend.zo_axpy_inplace(buf, op.len, op.seed, op.coeff)?;
                    }
                }
            }
        }
        times.perturb_secs += t.lap();

        let mut pool = pool.borrow_mut();
        let gathered = pool.run_plan(plan, &unit_ids, &base_ids, peft, batch, &snapshot)?;
        ensure!(
            gathered.len() == plan.evals.len(),
            "sharded gather is missing an eval result"
        );
        let mut losses: Vec<f32> = gathered.iter().map(|&l| l as f32).collect();
        times.forward_secs += t.lap();
        times.rt_secs += pool.take_rt();

        // fault hook + finiteness, in eval order (same semantics as the
        // sequential executor checking each loss as it lands)
        for e in 0..plan.evals.len() {
            if let Some(l) = inject(e)? {
                losses[e] = l;
            }
            if losses[e].is_finite() {
                continue;
            }
            // rollback-replay on the local replica — the exact op sequence
            // the sequential executor issues, from the exact same bits
            {
                let rep = &mut replicas[0];
                for (id, data) in &snapshot {
                    resolve_mut(&mut rep.bufs, *id)?.make_mut().copy_from_slice(data);
                }
                let Replica { backend, bufs: rb } = rep;
                'replay: for phase in &plan.phases {
                    match phase {
                        PlanPhase::Sweep(ops) => {
                            for op in ops {
                                let buf = resolve_mut(rb, unit_ids[op.unit])?;
                                backend.zo_axpy_inplace(buf, op.len, op.seed, op.coeff)?;
                            }
                        }
                        PlanPhase::Eval { idx } if *idx == e => break 'replay,
                        PlanPhase::Eval { .. } => {}
                    }
                }
                for op in &plan.recovery[e] {
                    let buf = resolve_mut(rb, unit_ids[op.unit])?;
                    backend.zo_axpy_inplace(buf, op.len, op.seed, op.coeff)?;
                }
            }
            // push the recovered bits to every live worker: lockstep again
            for (id, _) in &snapshot {
                let data = resolve(&replicas[0].bufs, *id)?.data().to_vec();
                pool.upload(*id, &data)?;
            }
            times.perturb_secs += t.lap();
            losses.truncate(e + 1);
            return Ok(PlanResult { losses, aborted: Some(e) });
        }
        Ok(PlanResult { losses, aborted: None })
    }
}

fn resolve<'m>(bufs: &'m HashMap<u64, NativeBuf>, id: u64) -> Result<&'m NativeBuf> {
    bufs.get(&id).ok_or_else(|| anyhow!("sharded: unknown buffer id {id} (already dropped?)"))
}

fn resolve_mut(bufs: &mut HashMap<u64, NativeBuf>, id: u64) -> Result<&mut NativeBuf> {
    bufs.get_mut(&id).ok_or_else(|| anyhow!("sharded: unknown buffer id {id} (already dropped?)"))
}

// the socket worker (`runtime/transport.rs`) keeps the same id->buffer map
// shape and error wording as an in-process replica
pub(crate) fn resolve_shared<'m>(bufs: &'m HashMap<u64, NativeBuf>, id: u64) -> Result<&'m NativeBuf> {
    resolve(bufs, id)
}

pub(crate) fn resolve_shared_mut(
    bufs: &mut HashMap<u64, NativeBuf>,
    id: u64,
) -> Result<&mut NativeBuf> {
    resolve_mut(bufs, id)
}

/// Resolve the forward-argument prefix (frozen base units, then tunable
/// units) inside one replica's buffer map.
fn resolve_args<'m>(
    bufs: &'m HashMap<u64, NativeBuf>,
    base_ids: &[u64],
    unit_ids: &[u64],
) -> Result<Vec<&'m NativeBuf>> {
    base_ids.iter().chain(unit_ids).map(|&id| resolve(bufs, id)).collect()
}

/// One replica's walk of the plan: apply **every** sweep phase in order
/// (lockstep), evaluate exactly the evals in `owned`, return `(eval idx,
/// loss)` scalars — the only data that crosses the worker boundary. Shared
/// by the in-process thread workers (which derive `owned` from
/// [`shard_owner`]) and by `lezo worker` processes
/// (`runtime/transport.rs`), which receive `owned` explicitly on the wire.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_plan_on_replica(
    backend: &NativeBackend,
    bufs: &mut HashMap<u64, NativeBuf>,
    plan: &StepPlan,
    unit_ids: &[u64],
    base_ids: &[u64],
    peft: PeftMode,
    batch: &Batch,
    owned: &std::collections::BTreeSet<usize>,
) -> Result<Vec<(usize, f64)>> {
    let mut gathered = Vec::new();
    for phase in &plan.phases {
        match phase {
            PlanPhase::Sweep(ops) => {
                for op in ops {
                    let buf = resolve_mut(bufs, unit_ids[op.unit])?;
                    backend.zo_axpy_inplace(buf, op.len, op.seed, op.coeff)?;
                }
            }
            PlanPhase::Eval { idx } => {
                if owned.contains(idx) {
                    let args = resolve_args(bufs, base_ids, unit_ids)?;
                    let l = backend.forward_loss(peft, &args, batch)?;
                    gathered.push((*idx, l as f64));
                }
            }
        }
    }
    Ok(gathered)
}

/// The thread-mode worker body: derive the owned eval set from the
/// round-robin cover, then walk the plan.
#[allow(clippy::too_many_arguments)]
fn worker_run(
    backend: &NativeBackend,
    bufs: &mut HashMap<u64, NativeBuf>,
    plan: &StepPlan,
    unit_ids: &[u64],
    base_ids: &[u64],
    peft: PeftMode,
    batch: &Batch,
    w: usize,
    shards: usize,
) -> Result<Vec<(usize, f64)>> {
    let mut owned = std::collections::BTreeSet::new();
    for idx in 0..plan.evals.len() {
        if shard_owner(idx, shards)? == w {
            owned.insert(idx);
        }
    }
    run_plan_on_replica(backend, bufs, plan, unit_ids, base_ids, peft, batch, &owned)
}

impl Backend for ShardedBackend {
    type Buffer = ShardBuf;
    type PreparedBatch = Batch;

    fn name(&self) -> &'static str {
        "sharded"
    }

    fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    fn upload(&self, data: &[f32]) -> Result<ShardBuf> {
        self.gc();
        let id = self.fresh_id();
        self.each_replica(|backend, bufs| {
            bufs.insert(id, backend.upload(data)?);
            Ok(())
        })?;
        self.remote_mirror(|pool| pool.upload(id, data))?;
        Ok(self.handle(id, data.len()))
    }

    fn download(&self, buf: &ShardBuf) -> Result<Vec<f32>> {
        let replicas = self.replicas.borrow();
        let rep = &replicas[0];
        rep.backend.download(resolve(&rep.bufs, buf.id)?)
    }

    fn zo_axpy(&self, unit: &ShardBuf, len: usize, seed: i32, coeff: f32) -> Result<ShardBuf> {
        self.gc();
        let id = self.fresh_id();
        self.each_replica(|backend, bufs| {
            let out = backend.zo_axpy(resolve(bufs, unit.id)?, len, seed, coeff)?;
            bufs.insert(id, out);
            Ok(())
        })?;
        self.remote_mirror(|pool| pool.axpy_alloc(unit.id, id, len, seed, coeff))?;
        Ok(self.handle(id, len))
    }

    fn zo_axpy_masked(
        &self,
        unit: &ShardBuf,
        pref: &ShardBuf,
        tau: f32,
        len: usize,
        seed: i32,
        coeff: f32,
    ) -> Result<ShardBuf> {
        self.gc();
        let id = self.fresh_id();
        self.each_replica(|backend, bufs| {
            let (u, p) = (resolve(bufs, unit.id)?, resolve(bufs, pref.id)?);
            let out = backend.zo_axpy_masked(u, p, tau, len, seed, coeff)?;
            bufs.insert(id, out);
            Ok(())
        })?;
        self.remote_mirror(|pool| pool.axpy_masked_alloc(unit.id, pref.id, id, tau, len, seed, coeff))?;
        Ok(self.handle(id, len))
    }

    fn zo_axpy_inplace(
        &self,
        unit: &mut ShardBuf,
        len: usize,
        seed: i32,
        coeff: f32,
    ) -> Result<()> {
        // broadcast: every replica applies the identical seeded sweep
        let id = unit.id;
        self.each_replica(|backend, bufs| {
            backend.zo_axpy_inplace(resolve_mut(bufs, id)?, len, seed, coeff)
        })?;
        self.remote_mirror(|pool| pool.axpy_inplace(id, len, seed, coeff))
    }

    fn zo_axpy_masked_inplace(
        &self,
        unit: &mut ShardBuf,
        pref: &ShardBuf,
        tau: f32,
        len: usize,
        seed: i32,
        coeff: f32,
    ) -> Result<()> {
        let (id, pid) = (unit.id, pref.id);
        self.each_replica(|backend, bufs| {
            // two ids into one map: pull the snapshot ref around the &mut
            let pref_copy = resolve(bufs, pid)?.data().to_vec();
            let pref_buf = NativeBuf::from(pref_copy);
            backend.zo_axpy_masked_inplace(resolve_mut(bufs, id)?, &pref_buf, tau, len, seed, coeff)
        })?;
        self.remote_mirror(|pool| pool.axpy_masked_inplace(id, pid, tau, len, seed, coeff))
    }

    fn prepare_batch(&self, batch: &Batch) -> Result<Batch> {
        Ok(batch.clone())
    }

    fn forward_loss(&self, peft: PeftMode, units: &[&ShardBuf], batch: &Batch) -> Result<f32> {
        let replicas = self.replicas.borrow();
        let rep = &replicas[0];
        let args = units.iter().map(|u| resolve(&rep.bufs, u.id)).collect::<Result<Vec<_>>>()?;
        rep.backend.forward_loss(peft, &args, batch)
    }

    fn example_losses(
        &self,
        peft: PeftMode,
        units: &[&ShardBuf],
        batch: &Batch,
    ) -> Result<Vec<f32>> {
        let replicas = self.replicas.borrow();
        let rep = &replicas[0];
        let args = units.iter().map(|u| resolve(&rep.bufs, u.id)).collect::<Result<Vec<_>>>()?;
        rep.backend.example_losses(peft, &args, batch)
    }

    fn predict(&self, peft: PeftMode, units: &[&ShardBuf], batch: &Batch) -> Result<Vec<i32>> {
        let replicas = self.replicas.borrow();
        let rep = &replicas[0];
        let args = units.iter().map(|u| resolve(&rep.bufs, u.id)).collect::<Result<Vec<_>>>()?;
        rep.backend.predict(peft, &args, batch)
    }

    fn initial_params(&self, explicit_checkpoint: &str) -> Result<(Vec<Vec<f32>>, String)> {
        self.replicas.borrow()[0].backend.initial_params(explicit_checkpoint)
    }

    /// First-order training works on host vectors (no replica state), so
    /// delegating to one replica is exact.
    fn forward_backward(
        &self,
        host_units: &[Vec<f32>],
        batch: &Batch,
    ) -> Result<(f32, Vec<Vec<f32>>)> {
        self.replicas.borrow()[0].backend.forward_backward(host_units, batch)
    }

    fn supports_peft(&self, mode: PeftMode) -> bool {
        self.replicas.borrow()[0].backend.supports_peft(mode)
    }

    fn supports_fo(&self) -> bool {
        self.replicas.borrow()[0].backend.supports_fo()
    }

    fn precision(&self) -> Precision {
        self.precision
    }

    fn supports_precision(&self, precision: Precision) -> bool {
        self.replicas.borrow()[0].backend.supports_precision(precision)
    }

    fn supports_plan_fanout(&self) -> bool {
        true
    }

    fn run_zo_plan(
        &self,
        plan: &StepPlan,
        bufs: &mut [ShardBuf],
        peft: PeftMode,
        base: Option<&[ShardBuf]>,
        batch: &Batch,
        inject: &mut dyn FnMut(usize) -> Result<Option<f32>>,
        times: &mut StageTimes,
    ) -> Result<PlanResult> {
        self.gc();
        if let Some(pool) = &self.remote {
            return self.run_zo_plan_socket(pool, plan, bufs, peft, base, batch, inject, times);
        }
        let unit_ids: Vec<u64> = bufs.iter().map(|b| b.id).collect();
        let base_ids: Vec<u64> =
            base.map(|bs| bs.iter().map(|b| b.id).collect()).unwrap_or_default();
        let mut replicas = self.replicas.borrow_mut();
        let shards = replicas.len();
        let mut t = StageTimer::start();

        // pre-step snapshot of every unit the plan touches (replica 0 —
        // all replicas hold the same bits), for abort rollback
        let touched = plan.touched_units();
        let snapshot: Vec<(u64, Vec<f32>)> = touched
            .iter()
            .map(|&k| {
                let id = unit_ids[k];
                Ok((id, resolve(&replicas[0].bufs, id)?.data().to_vec()))
            })
            .collect::<Result<_>>()?;
        times.perturb_secs += t.lap();

        // fan out: one scoped thread per replica, each with its slice of
        // the coordinator's thread budget (see module docs on LEZO_THREADS)
        let total_threads = parallel::effective_threads();
        let gathered: Vec<Result<Vec<(usize, f64)>>> = std::thread::scope(|s| {
            let handles: Vec<_> = replicas
                .iter_mut()
                .enumerate()
                .map(|(w, rep)| {
                    let budget = shard_thread_budget(total_threads, shards, w);
                    let (unit_ids, base_ids) = (&unit_ids, &base_ids);
                    s.spawn(move || {
                        parallel::with_threads(budget, || {
                            let Replica { backend, bufs } = rep;
                            worker_run(
                                backend, bufs, plan, unit_ids, base_ids, peft, batch, w, shards,
                            )
                        })
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap_or_else(|_| Err(anyhow!("sharded worker panicked"))))
                .collect()
        });

        // gather (eval idx, loss) scalars — the only cross-worker data
        let mut losses = vec![f32::NAN; plan.evals.len()];
        let mut filled = vec![false; plan.evals.len()];
        for worker in gathered {
            for (idx, l) in worker? {
                losses[idx] = l as f32;
                filled[idx] = true;
            }
        }
        ensure!(filled.iter().all(|&f| f), "sharded gather is missing an eval result");
        times.forward_secs += t.lap();

        // fault hook + finiteness, in eval order — identical semantics to
        // the sequential executor checking each loss as it lands
        for e in 0..plan.evals.len() {
            if let Some(l) = inject(e)? {
                losses[e] = l;
            }
            if losses[e].is_finite() {
                continue;
            }
            // rollback-replay on every replica: restore the pre-step bits,
            // replay the sweeps preceding eval `e` in phase order, then the
            // eval's recovery ops — the exact op sequence the sequential
            // executor issued, from the exact same starting bits
            for rep in replicas.iter_mut() {
                for (id, data) in &snapshot {
                    resolve_mut(&mut rep.bufs, *id)?.make_mut().copy_from_slice(data);
                }
                let Replica { backend, bufs } = rep;
                'replay: for phase in &plan.phases {
                    match phase {
                        PlanPhase::Sweep(ops) => {
                            for op in ops {
                                let buf = resolve_mut(bufs, unit_ids[op.unit])?;
                                backend.zo_axpy_inplace(buf, op.len, op.seed, op.coeff)?;
                            }
                        }
                        PlanPhase::Eval { idx } if *idx == e => break 'replay,
                        PlanPhase::Eval { .. } => {}
                    }
                }
                for op in &plan.recovery[e] {
                    let buf = resolve_mut(bufs, unit_ids[op.unit])?;
                    backend.zo_axpy_inplace(buf, op.len, op.seed, op.coeff)?;
                }
            }
            times.perturb_secs += t.lap();
            losses.truncate(e + 1);
            return Ok(PlanResult { losses, aborted: Some(e) });
        }
        Ok(PlanResult { losses, aborted: None })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::spsa::{SpsaEngine, TunableUnits};

    #[test]
    fn shard_owner_is_an_exact_disjoint_cover() {
        // every (n, shards) — including shards > n — assigns each item to
        // exactly one in-range shard, and the assignment is deterministic
        for n in [0usize, 1, 2, 5, 16, 64] {
            for shards in 1usize..=8 {
                let mut per_shard = vec![0usize; shards];
                for item in 0..n {
                    let w = shard_owner(item, shards).unwrap();
                    assert!(w < shards, "n={n} shards={shards} item={item} -> {w}");
                    assert_eq!(w, shard_owner(item, shards).unwrap());
                    per_shard[w] += 1;
                }
                assert_eq!(per_shard.iter().sum::<usize>(), n, "cover must be exact");
                // near-even: no shard holds more than ceil(n/shards)
                assert!(per_shard.iter().all(|&c| c <= n.div_ceil(shards)));
            }
        }
    }

    #[test]
    fn zero_shards_is_a_hard_error() {
        let err = shard_owner(3, 0).unwrap_err().to_string();
        assert!(err.contains(">= 1 shard"), "{err}");
        assert!(ShardedBackend::preset("opt-nano", 0).is_err());
        assert!(resolve_shards(0).is_err() || env_shards().unwrap().is_some());
    }

    #[test]
    fn shards_env_parse_is_strict() {
        assert!(parse_shards("").unwrap().is_none());
        assert_eq!(parse_shards("1").unwrap(), Some(1));
        assert_eq!(parse_shards("4").unwrap(), Some(4));
        for bad in ["bogus", "0", "-2", "1.5", " 3"] {
            let err = parse_shards(bad).unwrap_err().to_string();
            assert!(err.contains("LEZO_SHARDS"), "'{bad}': {err}");
            assert!(err.contains(bad), "'{bad}': {err}");
        }
    }

    #[test]
    fn thread_budget_splits_without_starving() {
        for total in [1usize, 2, 3, 7, 16] {
            for shards in 1usize..=5 {
                let budgets: Vec<usize> =
                    (0..shards).map(|w| shard_thread_budget(total, shards, w)).collect();
                assert!(budgets.iter().all(|&b| b >= 1), "{total}/{shards}: {budgets:?}");
                if total >= shards {
                    assert_eq!(budgets.iter().sum::<usize>(), total, "{total}/{shards}");
                }
            }
        }
    }

    #[test]
    fn broadcast_sweeps_keep_replicas_in_lockstep() {
        let b = ShardedBackend::preset("opt-nano", 3).unwrap();
        let host: Vec<f32> = (0..512).map(|i| (i as f32 * 0.01).sin()).collect();
        let mut buf = b.upload(&host).unwrap();
        b.zo_axpy_inplace(&mut buf, 512, 17, 1e-2).unwrap();
        let replicas = b.replicas.borrow();
        let first = replicas[0].bufs.get(&buf.id).unwrap().data().to_vec();
        assert_ne!(first, host, "sweep must move the params");
        for (w, rep) in replicas.iter().enumerate() {
            assert_eq!(rep.bufs.get(&buf.id).unwrap().data(), &first[..], "replica {w}");
        }
        drop(replicas);
        assert_eq!(b.download(&buf).unwrap(), first);
    }

    #[test]
    fn dropped_handles_are_garbage_collected_from_every_replica() {
        let b = ShardedBackend::preset("opt-nano", 2).unwrap();
        let id = {
            let buf = b.upload(&[1.0, 2.0, 3.0]).unwrap();
            buf.id
        };
        // drop queued; the next backend entry sweeps it
        let _other = b.upload(&[4.0]).unwrap();
        let replicas = b.replicas.borrow();
        for (w, rep) in replicas.iter().enumerate() {
            assert!(!rep.bufs.contains_key(&id), "replica {w} leaked buffer {id}");
        }
    }

    #[test]
    fn fanout_step_matches_sequential_bitwise_on_a_real_forward() {
        // the in-module smoke of the tentpole invariant (the full matrix
        // lives in rust/tests/backend_comparison.rs): one engine stepping a
        // native backend sequentially vs one stepping a 2-shard backend
        // through run_zo_plan must agree to_bits on losses and params
        use crate::coordinator::metrics::StageTimes;
        use crate::coordinator::optim::ZoSgd;

        let native = NativeBackend::preset("opt-nano").unwrap();
        let sharded = ShardedBackend::preset("opt-nano", 2).unwrap();
        let host = native.initial_params("").unwrap().0;
        let mut nat_units = TunableUnits::from_host(&native, &host).unwrap();
        let mut sh_units = TunableUnits::from_host(&sharded, &host).unwrap();
        let seqs: Vec<Vec<u32>> = (0..native.spec().train_batch)
            .map(|r| (0..12u32).map(|i| 20 + ((r as u32 + i) % 50)).collect())
            .collect();
        let batch = Batch::lm_batch(&seqs, native.spec().train_batch, 16).unwrap();
        let nat_prepared = native.prepare_batch(&batch).unwrap();
        let sh_prepared = sharded.prepare_batch(&batch).unwrap();

        let nat_eng = SpsaEngine::new(&native, 1e-3, 11).unwrap();
        let sh_eng = SpsaEngine::new(&sharded, 1e-3, 11).unwrap();
        let active: Vec<usize> = (0..nat_units.n_units()).filter(|&k| k != 1).collect();
        let mut times = StageTimes::default();
        for step in 0..2 {
            let mut nat_loss = |u: &TunableUnits<NativeBackend>| {
                native.forward_loss(PeftMode::Full, &u.unit_refs(), &nat_prepared)
            };
            let a = nat_eng
                .zo_step_opt(
                    step,
                    &mut nat_units,
                    &active,
                    1e-3,
                    &mut ZoSgd,
                    &mut nat_loss,
                    &mut times,
                )
                .unwrap();
            let c = sh_eng
                .zo_step_fanout(
                    step,
                    &mut sh_units,
                    &active,
                    1e-3,
                    &mut ZoSgd,
                    PeftMode::Full,
                    None,
                    &sh_prepared,
                    &mut |_| Ok(None),
                    &mut times,
                )
                .unwrap();
            assert_eq!(a.loss_plus.to_bits(), c.loss_plus.to_bits(), "step {step}");
            assert_eq!(a.loss_minus.to_bits(), c.loss_minus.to_bits(), "step {step}");
            assert_eq!(a.projected_grad.to_bits(), c.projected_grad.to_bits(), "step {step}");
        }
        assert_eq!(
            nat_units.to_host(&native).unwrap(),
            sh_units.to_host(&sharded).unwrap(),
            "sharded fan-out must be bit-identical to the sequential executor"
        );
    }

    #[test]
    fn fanout_without_executor_is_a_clear_error() {
        // a backend that never implemented run_zo_plan reports, not panics
        let native = NativeBackend::preset("opt-nano").unwrap();
        assert!(!native.supports_plan_fanout());
        let sharded = ShardedBackend::preset("opt-nano", 1).unwrap();
        assert!(sharded.supports_plan_fanout());
    }
}

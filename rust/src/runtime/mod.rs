//! The runtime layer: pluggable tensor/executable backends.
//!
//! ```text
//!   coordinator (SpsaEngine / Trainer / Evaluator / FoEngine)
//!        |            generic over runtime::backend::Backend
//!        v
//!   +----------------------+      +---------------------------------+
//!   | NativeBackend        |      | PjrtBackend  (feature "pjrt")   |
//!   |  Vec<f32> buffers    |      |  PjRtBuffer buffers             |
//!   |  philox z-regen      |      |  AOT HLO executables            |
//!   |  reference forward   |      |  (zo_axpy / forward families)   |
//!   +----------------------+      +---------------------------------+
//! ```
//!
//! - [`backend`] — the `Backend` trait + backend selection helpers.
//! - [`philox`] — counter-based Philox-4x32 Gaussian stream (native twin of
//!   the Pallas kernel; pinned to it by known-answer tests), including the
//!   multi-lane `fill_gauss` bulk fill the native sweeps stream through.
//! - [`native`] — pure-Rust CPU backend: zero artifacts, zero plugins.
//!   Hot path: scoped worker threads with fixed deterministic chunking
//!   (`native::parallel`), blocked kernels + fused streaming LM head
//!   (`native::kernels`), dense reference (`native::forward`).
//! - [`plan`] — the explicit `StepPlan`: one ZO step as ordered seeded-axpy
//!   sweeps + forward evaluations, the unit of distribution.
//! - [`sharded`] — `ShardedBackend`: N lockstep native worker replicas —
//!   in-process scoped threads (`shard_transport=thread`) or remote `lezo
//!   worker` processes (`shard_transport=socket`); a step's plan
//!   evaluations fan out across them and only `(probe, loss)` scalars come
//!   back.
//! - [`transport`] — the fault-tolerant framed socket protocol for socket
//!   mode: CRC'd length-prefixed frames, heartbeats, bounded
//!   retry-with-backoff, deterministic net fault injection, and
//!   degraded-mode continuation when workers die.
//! - [`client`] / [`exes`] / [`pjrt`] (feature `pjrt`) — the PJRT client,
//!   the lazily compiled executable registry, and the PJRT backend.

pub mod backend;
pub mod native;
pub mod philox;
pub mod plan;
pub mod sharded;
pub mod transport;

#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod exes;
#[cfg(feature = "pjrt")]
pub mod pjrt;

#[cfg(feature = "pjrt")]
pub use client::{run, run1, Runtime};
pub use backend::{Backend, BackendKind, Precision};
pub use native::{NativeBackend, NativeBuf};
pub use sharded::ShardedBackend;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;

//! Executable registry: manifest-driven, lazily compiled, cached.
//!
//! One compiled executable per (family, shape-key): zo_axpy is keyed by the
//! flat unit length, model executables by sequence bucket. Lazy compilation
//! keeps startup fast — a pure-ZO run never compiles forward_backward.

use crate::model::manifest::Manifest;
use crate::runtime::Runtime;
use anyhow::Result;
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Family {
    ZoAxpy,
    ZoAxpyMasked,
    ForwardLoss,
    ExampleLosses,
    Predict,
    ForwardBackward,
    // PEFT variants (exported with aot --peft)
    ForwardLossLora,
    ExampleLossesLora,
    PredictLora,
    ForwardLossPrefix,
    ExampleLossesPrefix,
    PredictPrefix,
}

impl Family {
    fn key(self, shape: usize) -> String {
        match self {
            Family::ZoAxpy => format!("zo_axpy_{shape}"),
            Family::ZoAxpyMasked => format!("zo_axpy_masked_{shape}"),
            Family::ForwardLoss => format!("forward_loss_s{shape}"),
            Family::ExampleLosses => format!("example_losses_s{shape}"),
            Family::Predict => format!("predict_s{shape}"),
            Family::ForwardBackward => format!("forward_backward_s{shape}"),
            Family::ForwardLossLora => format!("forward_loss_lora_s{shape}"),
            Family::ExampleLossesLora => format!("example_losses_lora_s{shape}"),
            Family::PredictLora => format!("predict_lora_s{shape}"),
            Family::ForwardLossPrefix => format!("forward_loss_prefix_s{shape}"),
            Family::ExampleLossesPrefix => format!("example_losses_prefix_s{shape}"),
            Family::PredictPrefix => format!("predict_prefix_s{shape}"),
        }
    }
}

/// Lazily compiled executable cache for one model's artifact directory.
pub struct ExeRegistry {
    manifest: Manifest,
    cache: RefCell<BTreeMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// compile count, for perf accounting / tests
    compiles: RefCell<usize>,
}

impl ExeRegistry {
    pub fn new(manifest: Manifest) -> Self {
        ExeRegistry { manifest, cache: RefCell::new(BTreeMap::new()), compiles: RefCell::new(0) }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn compiles(&self) -> usize {
        *self.compiles.borrow()
    }

    /// Fetch (compiling on first use) the executable for (family, shape).
    pub fn get(
        &self,
        rt: &Runtime,
        family: Family,
        shape: usize,
    ) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        let key = family.key(shape);
        if let Some(exe) = self.cache.borrow().get(&key) {
            return Ok(exe.clone());
        }
        let path = self.manifest.file_path(&key)?;
        let t = std::time::Instant::now();
        let exe = Rc::new(rt.load_exe(&path)?);
        *self.compiles.borrow_mut() += 1;
        crate::debug!("compiled {key} in {:.2}s", t.elapsed().as_secs_f64());
        self.cache.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }

    /// Pre-compile everything a ZO run needs (axpy for all unit lengths +
    /// forward_loss for all buckets), so step timing excludes compilation.
    pub fn warm_zo(&self, rt: &Runtime) -> Result<()> {
        for &n in &self.manifest.axpy_lens.clone() {
            self.get(rt, Family::ZoAxpy, n)?;
        }
        for &s in &self.manifest.seq_buckets.clone() {
            self.get(rt, Family::ForwardLoss, s)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::default_artifact_dir;
    use std::path::PathBuf;

    fn art() -> PathBuf {
        default_artifact_dir("opt-micro")
    }

    #[test]
    fn lazy_compile_and_cache() {
        crate::require_artifacts!();
        let rt = Runtime::cpu().unwrap();
        let reg = ExeRegistry::new(Manifest::load(&art()).unwrap());
        assert_eq!(reg.compiles(), 0);
        let n = reg.manifest().axpy_lens[0];
        let a = reg.get(&rt, Family::ZoAxpy, n).unwrap();
        assert_eq!(reg.compiles(), 1);
        let b = reg.get(&rt, Family::ZoAxpy, n).unwrap();
        assert_eq!(reg.compiles(), 1, "second fetch must hit the cache");
        assert!(Rc::ptr_eq(&a, &b));
    }

    #[test]
    fn unknown_shape_is_error() {
        crate::require_artifacts!();
        let rt = Runtime::cpu().unwrap();
        let reg = ExeRegistry::new(Manifest::load(&art()).unwrap());
        assert!(reg.get(&rt, Family::ZoAxpy, 123456789).is_err());
    }
}

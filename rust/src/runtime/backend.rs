//! The pluggable `Backend` abstraction: tensor storage, host transfer, and
//! the executable families (`ZoAxpy`, `ZoAxpyMasked` — each with an
//! in-place variant the SPSA sweeps route through — `ForwardLoss`,
//! `ExampleLosses`, `Predict`, `ForwardBackward`) behind one trait.
//!
//! Two implementations ship in-tree:
//!
//! - [`crate::runtime::native::NativeBackend`] — pure Rust: Philox-seeded
//!   Gaussian regeneration ([`crate::runtime::philox`]), native (masked)
//!   zo_axpy, a reference transformer forward *and backward* (so the FT
//!   baseline and pretraining run hermetically too), and native PEFT
//!   forwards (LoRA / prefix adapters folded into the blocked kernels).
//!   Zero external artifacts; this is what the hermetic test suite runs
//!   on.
//! - `PjrtBackend` (feature `pjrt`) — the PJRT runtime executing AOT HLO
//!   artifacts exported by `python/compile/aot.py`.
//!
//! The coordinator (`SpsaEngine`, `Trainer`, `Evaluator`, `FoEngine`) is
//! generic over this trait, so every algorithm invariant can be exercised
//! end-to-end on any machine, and future GPU / sharded runtimes slot in as
//! further implementations.

use crate::data::batch::Batch;
use crate::model::spec::ModelSpec;
use crate::peft::PeftMode;
use anyhow::Result;
use std::path::{Path, PathBuf};
use std::str::FromStr;

/// One tensor/executable substrate. `Buffer` is the device-resident flat
/// f32 tensor handle (natively a `NativeBuf` — an f32 master plus an
/// optional bf16 shadow; a `PjRtBuffer` under PJRT); `PreparedBatch` is an
/// uploaded (tokens, targets, mask) triple so the two forward probes of a
/// ZO step share one upload.
pub trait Backend {
    type Buffer;
    type PreparedBatch;

    fn name(&self) -> &'static str;

    /// The architecture this backend instance serves.
    fn spec(&self) -> &ModelSpec;

    // ---- host <-> device ---------------------------------------------------
    //
    // Transfers feed checkpointing (`TunableUnits::to_host` at every
    // `save_every` boundary) and resume (re-uploading saved masters), so
    // backends whose transfers can fail transiently should wrap them in
    // `util::retry_with_backoff` rather than surfacing one hiccup as a dead
    // run — the PJRT backend does; the native backend's "transfers" are
    // plain copies and cannot fail.

    fn upload(&self, data: &[f32]) -> Result<Self::Buffer>;
    fn download(&self, buf: &Self::Buffer) -> Result<Vec<f32>>;

    // ---- ZO kernels --------------------------------------------------------

    /// `out[i] = unit[i] + coeff * z(seed, i)` over one flat unit of `len`
    /// elements, with `z` regenerated from the Philox stream (never stored).
    fn zo_axpy(&self, unit: &Self::Buffer, len: usize, seed: i32, coeff: f32)
        -> Result<Self::Buffer>;

    /// Sparse-MeZO variant: `out[i] = unit[i] + coeff * z(seed, i) *
    /// [|pref[i]| <= tau]`. `pref` is the unperturbed step-start snapshot so
    /// the mask is stable across all four phases.
    fn zo_axpy_masked(
        &self,
        unit: &Self::Buffer,
        pref: &Self::Buffer,
        tau: f32,
        len: usize,
        seed: i32,
        coeff: f32,
    ) -> Result<Self::Buffer>;

    /// In-place `unit[i] += coeff * z(seed, i)` — what the four
    /// full-parameter sweeps of a ZO step (perturb / flip / restore /
    /// update) actually need. Host-resident backends override this to
    /// mutate with zero allocations; the default routes through the
    /// allocating [`Backend::zo_axpy`] and swaps the buffer, so device
    /// backends (PJRT) keep their executable path unchanged. Results must
    /// match the allocating path bit for bit.
    fn zo_axpy_inplace(
        &self,
        unit: &mut Self::Buffer,
        len: usize,
        seed: i32,
        coeff: f32,
    ) -> Result<()> {
        let out = self.zo_axpy(unit, len, seed, coeff)?;
        *unit = out;
        Ok(())
    }

    /// In-place twin of [`Backend::zo_axpy_masked`], same default-fallback
    /// contract as [`Backend::zo_axpy_inplace`].
    fn zo_axpy_masked_inplace(
        &self,
        unit: &mut Self::Buffer,
        pref: &Self::Buffer,
        tau: f32,
        len: usize,
        seed: i32,
        coeff: f32,
    ) -> Result<()> {
        let out = self.zo_axpy_masked(unit, pref, tau, len, seed, coeff)?;
        *unit = out;
        Ok(())
    }

    // ---- model executables -------------------------------------------------
    //
    // The three forward families are PEFT-aware: `units` is always the full
    // argument prefix — the frozen model units, then (under
    // `peft=lora|prefix`) one flat adapter unit per transformer block, in
    // block order. The adapter layout is defined once in [`crate::peft`]
    // (synced with `python/compile/peft.py`); both in-tree backends consume
    // it — natively the adapters fold into the blocked kernels, on PJRT
    // they are extra executable arguments. A backend reports which modes it
    // executes via [`Backend::supports_peft`].

    fn prepare_batch(&self, batch: &Batch) -> Result<Self::PreparedBatch>;

    /// Mean masked LM loss (the ZO objective). `units` is the full argument
    /// prefix: model units, then adapter units under PEFT.
    fn forward_loss(
        &self,
        peft: PeftMode,
        units: &[&Self::Buffer],
        batch: &Self::PreparedBatch,
    ) -> Result<f32>;

    /// Per-example mean masked loss (option scoring), one entry per batch row.
    fn example_losses(
        &self,
        peft: PeftMode,
        units: &[&Self::Buffer],
        batch: &Self::PreparedBatch,
    ) -> Result<Vec<f32>>;

    /// Greedy next-token prediction at every position, row-major `[rows*seq]`.
    fn predict(
        &self,
        peft: PeftMode,
        units: &[&Self::Buffer],
        batch: &Self::PreparedBatch,
    ) -> Result<Vec<i32>>;

    /// First-order substrate: (loss, per-unit grads) for the FT baseline and
    /// pretraining. Both in-tree backends implement it (native: the
    /// reference backward pass in `runtime/native/backward.rs`; PJRT: the
    /// AOT'd executable); a backend without autodiff leaves the default
    /// and reports [`Backend::supports_fo`] `== false`.
    fn forward_backward(
        &self,
        host_units: &[Vec<f32>],
        batch: &Batch,
    ) -> Result<(f32, Vec<Vec<f32>>)> {
        let _ = (host_units, batch);
        anyhow::bail!("the {} backend does not support first-order training", self.name())
    }

    // ---- run bootstrap -----------------------------------------------------

    /// Initial parameters for a run plus a human-readable source tag.
    /// `explicit_checkpoint` (config key `checkpoint`) overrides defaults.
    fn initial_params(&self, explicit_checkpoint: &str) -> Result<(Vec<Vec<f32>>, String)>;

    /// Which PEFT modes this backend can execute. The conservative default
    /// is full-parameter only; the native backend runs every mode with
    /// zero artifacts, PJRT needs the adapter executables in its manifest.
    fn supports_peft(&self, mode: PeftMode) -> bool {
        mode == PeftMode::Full
    }

    /// Flat length of one per-block adapter unit for `mode`. Backends with
    /// an artifact contract must cross-check this against their manifest so
    /// exporter drift fails loudly up front, not as an opaque shape error
    /// inside an executable.
    fn peft_unit_len(&self, mode: PeftMode) -> Result<usize> {
        Ok(match mode {
            PeftMode::Full => 0,
            PeftMode::Lora => crate::peft::lora_unit_len(self.spec().d_model),
            PeftMode::Prefix => crate::peft::prefix_unit_len(self.spec().d_model),
        })
    }

    fn supports_fo(&self) -> bool {
        false
    }

    /// The numeric precision this backend instance executes the forward
    /// families in. Perturbation/update state is f32 on every backend —
    /// precision is a forward-path property (see the native backend's
    /// bf16 shadow design in `runtime/native/mod.rs`).
    fn precision(&self) -> Precision {
        Precision::F32
    }

    /// Which precisions this backend can execute. The conservative default
    /// is f32 only; the native backend runs bf16 too (software bf16
    /// kernels), PJRT would need reduced-precision executables.
    fn supports_precision(&self, precision: Precision) -> bool {
        precision == Precision::F32
    }

    /// Pre-warm whatever a ZO run needs (e.g. compile executables) so step
    /// timing excludes one-time setup.
    fn warm_zo(&self) -> Result<()> {
        Ok(())
    }

    // ---- plan fan-out ------------------------------------------------------

    /// True when this backend owns its own [`StepPlan`] executor
    /// ([`Backend::run_zo_plan`]) that can fan a step's forward evaluations
    /// out across workers. Single-substrate backends leave the default:
    /// the engine then walks the plan sequentially itself — there is
    /// deliberately no second sequential executor here to drift from.
    fn supports_plan_fanout(&self) -> bool {
        false
    }

    /// Execute one [`StepPlan`] (sweeps + forward evaluations, *not* the
    /// optimizer update — the engine applies coefficients afterwards through
    /// [`Backend::zo_axpy_inplace`]). `bufs` are the tunable units the plan's
    /// ops index; `base` is the frozen argument prefix under PEFT. `inject`
    /// is the coordinator's per-eval hook (fault injection): called once per
    /// eval in eval order, `Ok(Some(l))` replaces that eval's loss before the
    /// finiteness check, and an `Err` aborts the step (an injected crash).
    /// On a non-finite loss the executor must leave the parameters exactly
    /// where the sequential executor would (see `runtime/plan.rs` on
    /// rollback-replay) and report `aborted`.
    #[allow(clippy::too_many_arguments)]
    fn run_zo_plan(
        &self,
        plan: &crate::runtime::plan::StepPlan,
        bufs: &mut [Self::Buffer],
        peft: PeftMode,
        base: Option<&[Self::Buffer]>,
        batch: &Self::PreparedBatch,
        inject: &mut dyn FnMut(usize) -> Result<Option<f32>>,
        times: &mut crate::coordinator::metrics::StageTimes,
    ) -> Result<crate::runtime::plan::PlanResult> {
        let _ = (plan, bufs, peft, base, batch, inject, times);
        anyhow::bail!(
            "the {} backend has no plan fan-out executor (Backend::supports_plan_fanout \
             is false); use the engine's sequential step path",
            self.name()
        )
    }
}

/// Which backend a run asks for (config key `backend`, env `LEZO_BACKEND`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// PJRT when artifacts exist (and the build has the `pjrt` feature),
    /// native otherwise.
    #[default]
    Auto,
    Native,
    /// N native worker replicas on scoped threads; a step's forward
    /// evaluations fan out across them (`shards` key / `LEZO_SHARDS` env).
    Sharded,
    Pjrt,
}

impl FromStr for BackendKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "auto" => BackendKind::Auto,
            "native" => BackendKind::Native,
            "sharded" => BackendKind::Sharded,
            "pjrt" | "xla" => BackendKind::Pjrt,
            _ => anyhow::bail!("unknown backend '{s}' (auto|native|sharded|pjrt)"),
        })
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            BackendKind::Auto => "auto",
            BackendKind::Native => "native",
            BackendKind::Sharded => "sharded",
            BackendKind::Pjrt => "pjrt",
        })
    }
}

/// Forward-path numeric precision (config key `precision`, env
/// `LEZO_PRECISION` — env wins, mirroring `threads`/`LEZO_THREADS`).
///
/// `bf16` halves the bytes the forward families *stream* (parameters and
/// activations are read as 2-byte bf16) on backends that support it. The
/// ZO-trainable f32 masters stay resident either way — natively the
/// shadows *add* ~0.5x parameter memory in exchange for the halved
/// traffic — and every algorithmic invariant (Philox regeneration,
/// perturb/flip/restore round-trip, thread-count invariance) is
/// precision-independent.
///
/// `int8`/`int4` stream block-quantized *weight* shadows instead (per-block
/// f32 absmax scale + packed integer codes; activations stay f32 — see
/// `runtime/native/quant.rs`): ~4x / ~7x fewer forward bytes than f32, at
/// the cost of quantization error in the weights only.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    #[default]
    F32,
    Bf16,
    Int8,
    Int4,
}

impl Precision {
    /// Block-quantized integer modes (weight shadows carry per-block
    /// scales; activations stay f32).
    pub fn is_quantized(self) -> bool {
        matches!(self, Precision::Int8 | Precision::Int4)
    }
}

impl FromStr for Precision {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" | "fp32" | "float32" => Precision::F32,
            "bf16" | "bfloat16" => Precision::Bf16,
            "int8" | "i8" => Precision::Int8,
            "int4" | "i4" => Precision::Int4,
            _ => anyhow::bail!("unknown precision '{s}' (f32|bf16|int8|int4)"),
        })
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Precision::F32 => "f32",
            Precision::Bf16 => "bf16",
            Precision::Int8 => "int8",
            Precision::Int4 => "int4",
        })
    }
}

/// `LEZO_PRECISION`: unset/empty means "no override"; anything else must
/// parse as a precision — an unparseable value is a hard error naming the
/// bad value (the same strictness rule as `LEZO_THREADS`), never a silent
/// fall-through to the default.
pub fn env_precision() -> Result<Option<Precision>> {
    match std::env::var("LEZO_PRECISION") {
        Err(_) => Ok(None),
        Ok(v) if v.is_empty() => Ok(None),
        Ok(v) => v
            .parse()
            .map(Some)
            .map_err(|_| {
                anyhow::anyhow!("LEZO_PRECISION='{v}' is not a precision (f32|bf16|int8|int4)")
            }),
    }
}

/// Resolve the precision for a run: the `LEZO_PRECISION` env override wins
/// (mirroring `LEZO_THREADS`), else the config key's value.
pub fn resolve_precision(requested: Precision) -> Result<Precision> {
    Ok(env_precision()?.unwrap_or(requested))
}

/// Does `dir` hold an AOT artifact set (manifest.json)?
pub fn artifacts_available(dir: &Path) -> bool {
    dir.join("manifest.json").exists()
}

/// Resolve the architecture for `(model, artifact dir)`: the manifest when
/// `dir` holds one (returned alongside, so callers parse it exactly once),
/// else the in-crate preset. This is the single definition of the fallback
/// rule — trainer, bench harness, and CLI all route through it.
pub fn resolve_model(
    model: &str,
    dir: &Path,
) -> Result<(ModelSpec, Option<crate::model::Manifest>)> {
    if artifacts_available(dir) {
        let manifest = crate::model::Manifest::load(dir)?;
        Ok((ModelSpec::from_manifest(&manifest), Some(manifest)))
    } else {
        Ok((ModelSpec::preset(model)?, None))
    }
}

/// Conventional artifact directory for a model size: `$LEZO_ARTIFACTS`
/// (default `artifacts`) joined with the size name. Tests and the
/// `require_artifacts!` macro route through here.
pub fn default_artifact_dir(model: &str) -> PathBuf {
    let root = std::env::var("LEZO_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    PathBuf::from(root).join(model)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_parse_display_round_trip() {
        for s in ["auto", "native", "sharded", "pjrt"] {
            let k: BackendKind = s.parse().unwrap();
            assert_eq!(k.to_string(), s);
        }
        assert_eq!("xla".parse::<BackendKind>().unwrap(), BackendKind::Pjrt);
        let err = "gpu".parse::<BackendKind>().unwrap_err().to_string();
        assert!(err.contains("auto|native|sharded|pjrt"), "{err}");
        assert_eq!(BackendKind::default(), BackendKind::Auto);
    }

    #[test]
    fn precision_parse_display_round_trip() {
        for s in ["f32", "bf16", "int8", "int4"] {
            let p: Precision = s.parse().unwrap();
            assert_eq!(p.to_string(), s);
        }
        assert_eq!("bfloat16".parse::<Precision>().unwrap(), Precision::Bf16);
        assert_eq!("fp32".parse::<Precision>().unwrap(), Precision::F32);
        assert_eq!("i8".parse::<Precision>().unwrap(), Precision::Int8);
        assert_eq!("i4".parse::<Precision>().unwrap(), Precision::Int4);
        let err = "fp8".parse::<Precision>().unwrap_err().to_string();
        assert!(err.contains("f32|bf16|int8|int4"), "{err}");
        assert_eq!(Precision::default(), Precision::F32);
        assert!(Precision::Int8.is_quantized() && Precision::Int4.is_quantized());
        assert!(!Precision::F32.is_quantized() && !Precision::Bf16.is_quantized());
    }

    #[test]
    fn artifact_dir_convention() {
        let d = default_artifact_dir("opt-micro");
        assert!(d.ends_with("opt-micro"));
        assert!(!artifacts_available(Path::new("/nonexistent/nowhere")));
    }
}

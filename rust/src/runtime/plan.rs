//! The explicit **StepPlan**: a data-first description of one ZO step.
//!
//! The coordinator ([`crate::coordinator::spsa::SpsaEngine`]) emits a plan —
//! the full ordered sequence of seeded axpy sweeps and forward evaluations a
//! step performs — and an executor runs it. Because every perturbation is
//! regenerated from its `(step, probe, unit)` seed inside the backend's
//! zo_axpy kernel, the plan is a handful of scalars: nothing parameter-sized
//! ever travels, which is what makes the step distributable.
//!
//! Two executors exist:
//!
//! - the **sequential** executor inside `SpsaEngine::zo_step_opt` — walks the
//!   phases in order against one backend; this is the trivial case and is
//!   *structurally* the pre-plan step (one code path, pinned bit-identical by
//!   `plan_executor_is_bit_identical_to_zo_step`);
//! - a backend-owned **fan-out** executor ([`super::backend::Backend::run_zo_plan`],
//!   implemented by [`super::sharded::ShardedBackend`]) — every worker replica
//!   applies the same sweeps locally and only `(probe, loss)` scalars are
//!   gathered.
//!
//! ## Abort semantics (non-finite losses)
//!
//! The sequential executor checks each evaluation as it happens and stops at
//! the first non-finite loss, applying that eval's `recovery` sweep so the
//! parameters end exactly where the imperative step left them. A fan-out
//! executor learns about the bad loss only after its workers ran the whole
//! plan, so it must *roll back*: restore the pre-step snapshot, replay every
//! sweep phase that precedes the failing eval in phase order, then apply the
//! same `recovery` ops. Both roads issue the identical op sequence from the
//! identical starting bits, so the post-abort parameters agree `to_bits`.

use crate::coordinator::optim::ProbeSchedule;

/// One seeded axpy: `unit += coeff * z(seed)`. The seed is precomputed at
/// plan-build time (via [`crate::rng::zo_probe_seed`]), so executors never
/// need the run seed or the probe index — the op is self-contained.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SweepOp {
    pub unit: usize,
    /// Element count of the unit (the axpy kernel's length argument).
    pub len: usize,
    pub seed: i32,
    pub coeff: f32,
}

/// One phase of a step: a batch of sweeps applied in order, or a forward
/// evaluation of the current parameters.
#[derive(Debug, Clone, PartialEq)]
pub enum PlanPhase {
    Sweep(Vec<SweepOp>),
    /// Evaluate the loss; `idx` indexes [`StepPlan::evals`] /
    /// [`StepPlan::recovery`] and the gathered loss vector.
    Eval { idx: usize },
}

/// Metadata of one forward evaluation (today just which probe it belongs
/// to, for error messages and the `(probe, loss)` gather pairs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EvalSpec {
    pub probe: u64,
}

/// The full plan of one ZO step *up to* (not including) the optimizer
/// update: which sweeps to apply and which forwards to run, in order.
/// The update coefficients depend on the gathered losses, so the engine
/// applies them after execution (broadcast through `zo_axpy_inplace`).
#[derive(Debug, Clone, PartialEq)]
pub struct StepPlan {
    pub step: u64,
    pub schedule: ProbeSchedule,
    pub phases: Vec<PlanPhase>,
    /// One entry per `Eval` phase, indexed by its `idx`.
    pub evals: Vec<EvalSpec>,
    /// `recovery[e]`: sweeps to apply when aborting at eval `e`, after the
    /// sweeps preceding that eval (see module docs on abort semantics).
    pub recovery: Vec<Vec<SweepOp>>,
}

impl StepPlan {
    /// Every unit any sweep (including recovery) touches — the set a
    /// fan-out executor must snapshot for rollback.
    pub fn touched_units(&self) -> Vec<usize> {
        let mut seen = std::collections::BTreeSet::new();
        for phase in &self.phases {
            if let PlanPhase::Sweep(ops) = phase {
                seen.extend(ops.iter().map(|op| op.unit));
            }
        }
        for ops in &self.recovery {
            seen.extend(ops.iter().map(|op| op.unit));
        }
        seen.into_iter().collect()
    }
}

/// What executing a plan produced: one loss per completed evaluation, in
/// eval order. `aborted = Some(e)` means eval `e` came back non-finite —
/// `losses[e]` holds the offending value, later evals were discarded, and
/// the executor has already restored the parameters to the abort state.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanResult {
    pub losses: Vec<f32>,
    pub aborted: Option<usize>,
}

//! PjrtBackend: the PJRT implementation of [`Backend`] (feature `pjrt`).
//!
//! Wraps the PJRT CPU client plus the manifest-driven executable registry:
//! buffers are device-resident `PjRtBuffer`s, the ZO kernels and forward
//! families execute AOT HLO artifacts exported by `python/compile/aot.py`.
//! Scalar coefficients are cached device-side so the four axpy phases of a
//! step do not re-upload `+mu` / `-2mu` per unit.

use crate::data::batch::Batch;
use crate::model::spec::ModelSpec;
use crate::model::{checkpoint, Manifest};
use crate::peft::PeftMode;
use crate::runtime::backend::Backend;
use crate::runtime::exes::{ExeRegistry, Family};
use crate::runtime::{run1, Runtime};
use anyhow::{ensure, Context, Result};
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;

/// Wall-clock budget for one host<->device transfer including all retries.
/// Generous (transfers are milliseconds even for the largest units), but
/// finite: a wedged runtime surfaces as a named error instead of a hang.
const TRANSFER_DEADLINE: std::time::Duration = std::time::Duration::from_secs(30);

pub struct PjrtBackend {
    rt: Runtime,
    reg: ExeRegistry,
    spec: ModelSpec,
    /// Device scalars keyed by f32 bit pattern (coefficients, taus), at
    /// most 64 resident. Promotion requires a *non-consecutive* repeat —
    /// i.e. the value recurs across sweeps (+mu, -2mu, taus) — so a
    /// per-step update coefficient (-lr*g), which only repeats within its
    /// own sweep, never occupies a permanent slot.
    scalars: RefCell<BTreeMap<u32, Rc<xla::PjRtBuffer>>>,
    /// Most recent upload: serves the within-sweep reuse (one upload per
    /// sweep for the update coefficient, matching the pre-refactor engine).
    last_scalar: RefCell<Option<(u32, Rc<xla::PjRtBuffer>)>>,
    /// Bit patterns seen before (promotion log for `scalars`).
    seen_once: RefCell<std::collections::BTreeSet<u32>>,
}

/// One uploaded (tokens, targets, mask) triple.
pub struct PjrtBatch {
    pub tok: xla::PjRtBuffer,
    pub tgt: xla::PjRtBuffer,
    pub msk: xla::PjRtBuffer,
    pub rows: usize,
    pub seq: usize,
}

impl PjrtBackend {
    /// Open the artifact directory (manifest + lazily compiled executables).
    pub fn open(artifact_dir: &Path) -> Result<PjrtBackend> {
        let rt = Runtime::cpu()?;
        let manifest = Manifest::load(artifact_dir)?;
        let spec = ModelSpec::from_manifest(&manifest);
        Ok(PjrtBackend {
            rt,
            reg: ExeRegistry::new(manifest),
            spec,
            scalars: RefCell::new(BTreeMap::new()),
            last_scalar: RefCell::new(None),
            seen_once: RefCell::new(std::collections::BTreeSet::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        self.reg.manifest()
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    pub fn registry(&self) -> &ExeRegistry {
        &self.reg
    }

    fn scalar_cached(&self, v: f32) -> Result<Rc<xla::PjRtBuffer>> {
        let key = v.to_bits();
        if let Some(b) = self.scalars.borrow().get(&key) {
            return Ok(b.clone());
        }
        if let Some((k, b)) = &*self.last_scalar.borrow() {
            if *k == key {
                // consecutive reuse: the same coefficient swept across units
                return Ok(b.clone());
            }
        }
        let b = Rc::new(self.rt.scalar_f32(v)?);
        let first_sighting = {
            let mut seen = self.seen_once.borrow_mut();
            if seen.len() >= 4096 {
                seen.clear(); // bound the sighting log, not the hot cache
            }
            seen.insert(key)
        };
        if !first_sighting {
            // a NON-consecutive repeat (the MRU slot above absorbed the
            // within-sweep ones): this value recurs across sweeps
            // (mu, -2mu, tau) — keep it device-resident for the run. Hard
            // cap so pathological coefficient recurrence cannot grow the
            // resident set unboundedly; hot values are promoted within the
            // first steps, so a full cache just stops admitting newcomers.
            let mut cache = self.scalars.borrow_mut();
            if cache.len() < 64 {
                cache.insert(key, b.clone());
            }
        }
        *self.last_scalar.borrow_mut() = Some((key, b.clone()));
        Ok(b)
    }

    fn families(&self, peft: PeftMode) -> (Family, Family, Family) {
        match peft {
            PeftMode::Full => (Family::ForwardLoss, Family::ExampleLosses, Family::Predict),
            PeftMode::Lora => {
                (Family::ForwardLossLora, Family::ExampleLossesLora, Family::PredictLora)
            }
            PeftMode::Prefix => {
                (Family::ForwardLossPrefix, Family::ExampleLossesPrefix, Family::PredictPrefix)
            }
        }
    }
}

impl Backend for PjrtBackend {
    type Buffer = xla::PjRtBuffer;
    type PreparedBatch = PjrtBatch;

    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    fn upload(&self, data: &[f32]) -> Result<xla::PjRtBuffer> {
        // host->device transfers are the one backend edge that can fail
        // transiently on real accelerator runtimes (the CPU client never
        // does, so the first attempt always wins there); bounded
        // retry-with-backoff keeps a mid-run checkpoint download or a resume
        // upload from killing hours of training on a hiccup. The wall-clock
        // deadline bounds the whole retry loop too, so a runtime that blocks
        // instead of erroring cannot stall a transfer indefinitely.
        crate::util::retry_with_backoff_deadline(
            "pjrt upload",
            3,
            10,
            Some(std::time::Instant::now() + TRANSFER_DEADLINE),
            || self.rt.vec_f32(data),
        )
    }

    fn download(&self, buf: &xla::PjRtBuffer) -> Result<Vec<f32>> {
        crate::util::retry_with_backoff_deadline(
            "pjrt download",
            3,
            10,
            Some(std::time::Instant::now() + TRANSFER_DEADLINE),
            || self.rt.read_vec_f32(buf),
        )
    }

    fn zo_axpy(
        &self,
        unit: &xla::PjRtBuffer,
        len: usize,
        seed: i32,
        coeff: f32,
    ) -> Result<xla::PjRtBuffer> {
        let exe = self.reg.get(&self.rt, Family::ZoAxpy, len)?;
        let seed_b = self.rt.scalar_i32(seed)?;
        let c = self.scalar_cached(coeff)?;
        run1(&exe, &[unit, &seed_b, c.as_ref()])
    }

    fn zo_axpy_masked(
        &self,
        unit: &xla::PjRtBuffer,
        pref: &xla::PjRtBuffer,
        tau: f32,
        len: usize,
        seed: i32,
        coeff: f32,
    ) -> Result<xla::PjRtBuffer> {
        let exe = self.reg.get(&self.rt, Family::ZoAxpyMasked, len)?;
        let seed_b = self.rt.scalar_i32(seed)?;
        let tau_b = self.scalar_cached(tau)?;
        let c = self.scalar_cached(coeff)?;
        run1(&exe, &[unit, pref, tau_b.as_ref(), &seed_b, c.as_ref()])
    }

    fn prepare_batch(&self, batch: &Batch) -> Result<PjrtBatch> {
        Ok(PjrtBatch {
            tok: self.rt.mat_i32(&batch.tokens, batch.rows, batch.seq)?,
            tgt: self.rt.mat_i32(&batch.targets, batch.rows, batch.seq)?,
            msk: self.rt.mat_f32(&batch.mask, batch.rows, batch.seq)?,
            rows: batch.rows,
            seq: batch.seq,
        })
    }

    fn forward_loss(
        &self,
        peft: PeftMode,
        units: &[&xla::PjRtBuffer],
        batch: &PjrtBatch,
    ) -> Result<f32> {
        let (fam, _, _) = self.families(peft);
        let exe = self.reg.get(&self.rt, fam, batch.seq)?;
        let mut args: Vec<&xla::PjRtBuffer> = units.to_vec();
        args.push(&batch.tok);
        args.push(&batch.tgt);
        args.push(&batch.msk);
        let out = run1(&exe, &args)?;
        self.rt.read_scalar_f32(&out)
    }

    fn example_losses(
        &self,
        peft: PeftMode,
        units: &[&xla::PjRtBuffer],
        batch: &PjrtBatch,
    ) -> Result<Vec<f32>> {
        let (_, fam, _) = self.families(peft);
        let exe = self.reg.get(&self.rt, fam, batch.seq)?;
        let mut args: Vec<&xla::PjRtBuffer> = units.to_vec();
        args.push(&batch.tok);
        args.push(&batch.tgt);
        args.push(&batch.msk);
        let out = run1(&exe, &args)?;
        let per = self.rt.read_vec_f32(&out)?;
        ensure!(per.len() == batch.rows, "example_losses returned {} rows", per.len());
        Ok(per)
    }

    fn predict(
        &self,
        peft: PeftMode,
        units: &[&xla::PjRtBuffer],
        batch: &PjrtBatch,
    ) -> Result<Vec<i32>> {
        let (_, _, fam) = self.families(peft);
        let exe = self.reg.get(&self.rt, fam, batch.seq)?;
        let mut args: Vec<&xla::PjRtBuffer> = units.to_vec();
        args.push(&batch.tok);
        let out = run1(&exe, &args)?;
        let preds = self.rt.read_vec_i32(&out)?;
        ensure!(preds.len() == batch.rows * batch.seq, "predict shape mismatch");
        Ok(preds)
    }

    fn forward_backward(
        &self,
        host_units: &[Vec<f32>],
        batch: &Batch,
    ) -> Result<(f32, Vec<Vec<f32>>)> {
        let exe = self.reg.get(&self.rt, Family::ForwardBackward, batch.seq)?;
        let mut args: Vec<xla::PjRtBuffer> = Vec::with_capacity(host_units.len() + 3);
        for u in host_units {
            args.push(self.rt.vec_f32(u)?);
        }
        args.push(self.rt.mat_i32(&batch.tokens, batch.rows, batch.seq)?);
        args.push(self.rt.mat_i32(&batch.targets, batch.rows, batch.seq)?);
        args.push(self.rt.mat_f32(&batch.mask, batch.rows, batch.seq)?);
        let refs: Vec<&xla::PjRtBuffer> = args.iter().collect();
        let out = run1(&exe, &refs)?;
        let parts = self.rt.read_tuple(&out)?;
        ensure!(
            parts.len() == host_units.len() + 1,
            "forward_backward returned {} outputs, expected {}",
            parts.len(),
            host_units.len() + 1
        );
        let loss = parts[0].get_first_element::<f32>()?;
        let grads = parts[1..]
            .iter()
            .map(|l| Ok(l.to_vec::<f32>()?))
            .collect::<Result<Vec<_>>>()?;
        Ok((loss, grads))
    }

    fn initial_params(&self, explicit_checkpoint: &str) -> Result<(Vec<Vec<f32>>, String)> {
        checkpoint::resolve_initial(self.manifest(), explicit_checkpoint)
    }

    fn supports_peft(&self, mode: PeftMode) -> bool {
        match mode {
            PeftMode::Full => true,
            PeftMode::Lora => self.manifest().lora_unit_len.is_some(),
            PeftMode::Prefix => self.manifest().prefix_unit_len.is_some(),
        }
    }

    fn peft_unit_len(&self, mode: PeftMode) -> Result<usize> {
        let computed = match mode {
            PeftMode::Full => return Ok(0),
            PeftMode::Lora => crate::peft::lora_unit_len(self.spec.d_model),
            PeftMode::Prefix => crate::peft::prefix_unit_len(self.spec.d_model),
        };
        let exported = match mode {
            PeftMode::Full => unreachable!(),
            PeftMode::Lora => self.manifest().lora_unit_len,
            PeftMode::Prefix => self.manifest().prefix_unit_len,
        };
        let exported = exported.with_context(|| {
            format!("artifacts lack {mode} executables (re-run `aot --peft`)")
        })?;
        ensure!(
            exported == computed,
            "manifest {mode} unit length {exported} != in-crate adapter layout {computed} \
             (exporter drift: re-sync python/compile/peft.py with rust/src/peft/mod.rs)"
        );
        Ok(exported)
    }

    fn supports_fo(&self) -> bool {
        true
    }

    fn warm_zo(&self) -> Result<()> {
        self.reg.warm_zo(&self.rt)
    }
}

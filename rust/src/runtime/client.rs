//! PJRT client plumbing: load AOT HLO-text artifacts, compile once, execute
//! many (feature `pjrt`).
//!
//! Pattern from /opt/xla-example/load_hlo: HLO *text* -> HloModuleProto ->
//! XlaComputation -> PjRtLoadedExecutable. All hot-path calls use
//! `execute_b` over device-resident `PjRtBuffer`s; literals only appear at
//! the host boundary (batch upload, scalar readback).

use anyhow::{Context, Result};
use std::path::Path;

/// Thin wrapper over the PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an HLO-text artifact into an executable.
    pub fn load_exe(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))
    }

    // ---- host -> device uploads -------------------------------------------

    pub fn scalar_i32(&self, v: i32) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(&[v], &[], None)?)
    }

    pub fn scalar_f32(&self, v: f32) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(&[v], &[], None)?)
    }

    pub fn vec_f32(&self, data: &[f32]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, &[data.len()], None)?)
    }

    pub fn mat_i32(&self, data: &[i32], rows: usize, cols: usize) -> Result<xla::PjRtBuffer> {
        debug_assert_eq!(data.len(), rows * cols);
        Ok(self.client.buffer_from_host_buffer(data, &[rows, cols], None)?)
    }

    pub fn mat_f32(&self, data: &[f32], rows: usize, cols: usize) -> Result<xla::PjRtBuffer> {
        debug_assert_eq!(data.len(), rows * cols);
        Ok(self.client.buffer_from_host_buffer(data, &[rows, cols], None)?)
    }

    // ---- device -> host readback -------------------------------------------

    pub fn read_scalar_f32(&self, buf: &xla::PjRtBuffer) -> Result<f32> {
        Ok(buf.to_literal_sync()?.get_first_element::<f32>()?)
    }

    pub fn read_vec_f32(&self, buf: &xla::PjRtBuffer) -> Result<Vec<f32>> {
        Ok(buf.to_literal_sync()?.to_vec::<f32>()?)
    }

    pub fn read_vec_i32(&self, buf: &xla::PjRtBuffer) -> Result<Vec<i32>> {
        Ok(buf.to_literal_sync()?.to_vec::<i32>()?)
    }

    /// Read a tuple-rooted output (the forward_backward executable) into its
    /// component literals.
    pub fn read_tuple(&self, buf: &xla::PjRtBuffer) -> Result<Vec<xla::Literal>> {
        Ok(buf.to_literal_sync()?.to_tuple()?)
    }
}

/// Execute with a borrowed argument list (hot-path helper): takes the
/// executable and `&[&PjRtBuffer]`, returns the first replica's outputs.
pub fn run(
    exe: &xla::PjRtLoadedExecutable,
    args: &[&xla::PjRtBuffer],
) -> Result<Vec<xla::PjRtBuffer>> {
    let mut out = exe.execute_b(args)?;
    anyhow::ensure!(!out.is_empty(), "executable produced no replicas");
    Ok(out.swap_remove(0))
}

/// Execute expecting exactly one output buffer.
pub fn run1(
    exe: &xla::PjRtLoadedExecutable,
    args: &[&xla::PjRtBuffer],
) -> Result<xla::PjRtBuffer> {
    let mut outs = run(exe, args)?;
    anyhow::ensure!(outs.len() == 1, "expected 1 output, got {}", outs.len());
    Ok(outs.swap_remove(0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::default_artifact_dir;

    #[test]
    fn cpu_client_comes_up() {
        crate::require_artifacts!();
        let rt = Runtime::cpu().unwrap();
        assert_eq!(rt.platform(), "cpu");
    }

    #[test]
    fn scalar_round_trip() {
        crate::require_artifacts!();
        let rt = Runtime::cpu().unwrap();
        let b = rt.scalar_f32(3.25).unwrap();
        assert_eq!(rt.read_scalar_f32(&b).unwrap(), 3.25);
    }

    #[test]
    fn vec_round_trip() {
        crate::require_artifacts!();
        let rt = Runtime::cpu().unwrap();
        let data: Vec<f32> = (0..1000).map(|i| i as f32 * 0.5).collect();
        let b = rt.vec_f32(&data).unwrap();
        assert_eq!(rt.read_vec_f32(&b).unwrap(), data);
    }

    #[test]
    fn axpy_exe_runs_and_is_deterministic() {
        crate::require_artifacts!();
        let rt = Runtime::cpu().unwrap();
        let m = crate::model::Manifest::load(&default_artifact_dir("opt-micro")).unwrap();
        let n = m.axpy_lens[0];
        let exe = rt.load_exe(&m.file_path(&format!("zo_axpy_{n}")).unwrap()).unwrap();
        let p = rt.vec_f32(&vec![0.0; n]).unwrap();
        let seed = rt.scalar_i32(42).unwrap();
        let one = rt.scalar_f32(1.0).unwrap();
        let za = rt.read_vec_f32(&run1(&exe, &[&p, &seed, &one]).unwrap()).unwrap();
        let zb = rt.read_vec_f32(&run1(&exe, &[&p, &seed, &one]).unwrap()).unwrap();
        assert_eq!(za, zb, "same seed must regenerate the same z");
        // z is standard normal
        let mean: f32 = za.iter().sum::<f32>() / n as f32;
        let var: f32 = za.iter().map(|&x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.2, "mean={mean}");
        assert!((var - 1.0).abs() < 0.3, "var={var}");
    }

    #[test]
    fn pallas_stream_matches_native_philox() {
        // cross-backend contract: the AOT'd kernel's z stream must agree
        // with the native Philox port to float tolerance
        crate::require_artifacts!();
        let rt = Runtime::cpu().unwrap();
        let m = crate::model::Manifest::load(&default_artifact_dir("opt-micro")).unwrap();
        let n = m.axpy_lens[0];
        let exe = rt.load_exe(&m.file_path(&format!("zo_axpy_{n}")).unwrap()).unwrap();
        let p = rt.vec_f32(&vec![0.0; n]).unwrap();
        let seed = rt.scalar_i32(1234).unwrap();
        let one = rt.scalar_f32(1.0).unwrap();
        let z = rt.read_vec_f32(&run1(&exe, &[&p, &seed, &one]).unwrap()).unwrap();
        for (i, &zi) in z.iter().take(4096).enumerate() {
            let want = crate::runtime::philox::gauss_from_index(i as u32, 1234);
            assert!((zi - want).abs() < 3e-5, "idx {i}: pallas {zi} vs native {want}");
        }
    }

    #[test]
    fn axpy_perturb_restore_identity() {
        crate::require_artifacts!();
        let rt = Runtime::cpu().unwrap();
        let m = crate::model::Manifest::load(&default_artifact_dir("opt-micro")).unwrap();
        let n = m.axpy_lens[0];
        let exe = rt.load_exe(&m.file_path(&format!("zo_axpy_{n}")).unwrap()).unwrap();
        let orig: Vec<f32> = (0..n).map(|i| (i as f32 * 0.01).sin()).collect();
        let p0 = rt.vec_f32(&orig).unwrap();
        let seed = rt.scalar_i32(7).unwrap();
        let mu = 1e-3f32;
        let p1 = run1(&exe, &[&p0, &seed, &rt.scalar_f32(mu).unwrap()]).unwrap();
        let p2 = run1(&exe, &[&p1, &seed, &rt.scalar_f32(-2.0 * mu).unwrap()]).unwrap();
        let p3 = run1(&exe, &[&p2, &seed, &rt.scalar_f32(mu).unwrap()]).unwrap();
        let back = rt.read_vec_f32(&p3).unwrap();
        for (a, b) in back.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }
}

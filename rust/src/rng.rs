//! Deterministic RNG for the coordinator: layer selection, data generation,
//! and seed derivation. SplitMix64 core (Steele et al. 2014) — tiny, fast,
//! and good enough for everything that is *not* the perturbation stream
//! (which is Philox inside the L1 kernel; see python/compile/kernels).
//!
//! Everything the system samples flows through here so runs are exactly
//! reproducible from a single `run_seed`.

/// SplitMix64 PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // avoid the all-zeros fixed point neighbourhood by pre-mixing
        let mut r = Rng { state: seed ^ 0x9E3779B97F4A7C15 };
        r.next_u64();
        r
    }

    /// Derive an independent child stream (for subsystem isolation).
    pub fn child(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xBF58476D1CE4E5B9))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, n) without modulo bias (rejection).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (data-gen only; the perturbation
    /// stream lives in the L1 kernel).
    pub fn gaussian(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose one element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Sample k distinct indices from 0..n (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n - 1);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

/// Stable seed derivation for (run, step, purpose) triples. The ZO step seed
/// handed to the zo_axpy executable is `derive(run_seed, step, PURPOSE_ZO)`
/// truncated to a non-negative i32 (the kernel's seed input type).
pub fn derive(run_seed: u64, a: u64, b: u64) -> u64 {
    let mut r = Rng::new(run_seed ^ a.rotate_left(17) ^ b.rotate_left(41));
    r.next_u64()
}

/// Seed for the perturbation stream of (step, layer-unit). Must be stable:
/// the update phase regenerates the exact stream the perturb phase used.
pub fn zo_seed(run_seed: u64, step: u64, unit: usize) -> i32 {
    (derive(run_seed, step, unit as u64) & 0x7FFF_FFFF) as i32
}

/// Seed for probe `probe` of (step, layer-unit). Probe 0 IS the classic
/// SPSA direction — it must equal [`zo_seed`] bit-for-bit, both so
/// `zo_opt=zo-sgd` stays bit-identical to the pre-zoo trajectory and so
/// the seed-replay optimizers (momentum / Adam) regenerate exactly the
/// stream a past step perturbed with. Probes >= 1 are the extra
/// directions of the one-sided batched (FZOO-style) schedule.
pub fn zo_probe_seed(run_seed: u64, step: u64, probe: u64, unit: usize) -> i32 {
    if probe == 0 {
        zo_seed(run_seed, step, unit)
    } else {
        zo_seed(derive(run_seed, purpose::PROBE, probe), step, unit)
    }
}

pub mod purpose {
    pub const DATA: u64 = 0xDA7A;
    pub const SELECTOR: u64 = 0x5E1E;
    pub const EVAL: u64 = 0xE7A1;
    pub const INIT: u64 = 0x1217;
    /// Extra perturbation directions of the one-sided batched schedule.
    pub const PROBE: u64 = 0x9B0E;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn uniformity_chi_square_rough() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 16];
        let n = 16_000;
        for _ in 0..n {
            counts[r.below(16)] += 1;
        }
        let expect = (n / 16) as f64;
        let chi2: f64 = counts.iter().map(|&c| (c as f64 - expect).powi(2) / expect).sum();
        assert!(chi2 < 50.0, "chi2={chi2}"); // df=15, p<1e-5 threshold
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(13);
        for _ in 0..100 {
            let k = r.range(0, 20);
            let s = r.sample_indices(20, k);
            assert_eq!(s.len(), k);
            let mut dedup = s.clone();
            dedup.sort_unstable();
            dedup.dedup();
            assert_eq!(dedup.len(), k);
            assert!(s.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn sample_indices_uniform_coverage() {
        // property: each index appears ~k/n of the time
        let mut r = Rng::new(17);
        let (n, k, trials) = (10, 3, 10_000);
        let mut counts = vec![0usize; n];
        for _ in 0..trials {
            for i in r.sample_indices(n, k) {
                counts[i] += 1;
            }
        }
        let expect = trials * k / n;
        for (i, &c) in counts.iter().enumerate() {
            let dev = (c as f64 - expect as f64).abs() / expect as f64;
            assert!(dev < 0.1, "index {i}: {c} vs {expect}");
        }
    }

    #[test]
    fn zo_seed_stable_and_nonnegative() {
        let a = zo_seed(123, 45, 6);
        let b = zo_seed(123, 45, 6);
        assert_eq!(a, b);
        assert!(a >= 0);
        assert_ne!(zo_seed(123, 45, 6), zo_seed(123, 45, 7));
        assert_ne!(zo_seed(123, 45, 6), zo_seed(123, 46, 6));
    }

    #[test]
    fn probe_zero_is_the_classic_zo_seed() {
        // the bit-identity hinge of the optimizer zoo: probe 0 must be
        // indistinguishable from the pre-zoo seed derivation
        for (rs, step, unit) in [(0u64, 0u64, 0usize), (123, 45, 6), (7, 900, 3)] {
            assert_eq!(zo_probe_seed(rs, step, 0, unit), zo_seed(rs, step, unit));
        }
    }

    #[test]
    fn probe_seeds_are_stable_distinct_and_nonnegative() {
        let a = zo_probe_seed(123, 45, 2, 6);
        assert_eq!(a, zo_probe_seed(123, 45, 2, 6));
        assert!(a >= 0);
        assert_ne!(a, zo_probe_seed(123, 45, 1, 6), "probes must differ");
        assert_ne!(a, zo_probe_seed(123, 45, 0, 6));
        assert_ne!(a, zo_probe_seed(123, 46, 2, 6), "steps must differ");
        assert_ne!(a, zo_probe_seed(123, 45, 2, 7), "units must differ");
        assert_ne!(a, zo_probe_seed(124, 45, 2, 6), "runs must differ");
    }

    #[test]
    fn probe_seeds_are_pairwise_distinct_over_the_lattice() {
        // the sharded fan-out trusts every (step, probe, unit) to name a
        // unique perturbation stream; a collision would make two sweeps
        // silently share a direction. Property-check a sampled lattice —
        // small/large steps, the full probe range of a realistic one-sided
        // batch, every unit of a small model — for full pairwise
        // distinctness under a handful of run seeds.
        use std::collections::HashMap;
        for run_seed in [0u64, 7, 0xDEAD_BEEF] {
            let mut seen: HashMap<i32, (u64, u64, usize)> = HashMap::new();
            for &step in &[0u64, 1, 7, 63, 1000, 65_535] {
                for probe in 0u64..6 {
                    for unit in 0usize..8 {
                        let s = zo_probe_seed(run_seed, step, probe, unit);
                        assert!(s >= 0, "kernel seeds are non-negative i32");
                        if let Some(prev) = seen.insert(s, (step, probe, unit)) {
                            panic!(
                                "seed collision under run_seed {run_seed}: \
                                 {prev:?} and {:?} both map to {s}",
                                (step, probe, unit)
                            );
                        }
                    }
                }
            }
            assert_eq!(seen.len(), 6 * 6 * 8);
        }
    }

    #[test]
    fn child_streams_independent() {
        let mut root = Rng::new(1);
        let mut a = root.child(1);
        let mut b = root.child(2);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}

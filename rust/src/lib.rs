//! # LeZO — layer-wise sparse, computation- and memory-efficient zeroth-order fine-tuning
//!
//! Rust + JAX + Pallas (three-layer, AOT via XLA/PJRT) reproduction of
//! *"Simultaneous Computation and Memory Efficient Zeroth-Order Optimizer for
//! Fine-Tuning Large Language Models"* (Wang et al., 2024).
//!
//! Layering (see DESIGN.md):
//! - **L3 (this crate)**: the coordinator — layer selection ([`coordinator::selector`]),
//!   the SPSA/ZO-SGD engine ([`coordinator::spsa`]), the FO substrate
//!   ([`coordinator::fo`]), the trainer ([`coordinator::trainer`]), evaluation
//!   ([`eval`]) and the bench harness ([`bench`]).
//! - **Runtime**: [`runtime`] wraps the PJRT CPU client; AOT HLO-text artifacts
//!   from `python/compile/aot.py` are compiled once and executed many times.
//! - **L2/L1** live in `python/compile/` and never run on the request path.
//!
//! The crate is `anyhow + xla` only; everything else (JSON, RNG, stats,
//! CLI parsing, table rendering) is implemented in-repo for offline builds.

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod model;
pub mod peft;
pub mod rng;
pub mod runtime;
pub mod stats;
pub mod tasks;
pub mod util;

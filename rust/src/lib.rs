//! # LeZO — layer-wise sparse, computation- and memory-efficient zeroth-order fine-tuning
//!
//! Rust + JAX + Pallas (three-layer, AOT via XLA/PJRT) reproduction of
//! *"Simultaneous Computation and Memory Efficient Zeroth-Order Optimizer for
//! Fine-Tuning Large Language Models"* (Wang et al., 2024).
//!
//! The repo-level `ARCHITECTURE.md` is the map: the L1/L2/L3 layering,
//! the full [`runtime::Backend`] contract (executable families, in-place
//! axpy semantics, what a new GPU/sharded backend must implement), the
//! Philox seed-regeneration invariant, and the PEFT unit memory layout
//! shared with `python/compile/peft.py`.
//!
//! ## Layering
//!
//! ```text
//!   L3  coordinator (this crate): layer selection, SPSA/ZO-SGD engine,
//!       FO substrate, trainer, eval, bench harness
//!        |
//!        |  generic over runtime::backend::Backend
//!        v
//!   +--------------------------+   +----------------------------------+
//!   | NativeBackend            |   | PjrtBackend   (feature "pjrt")   |
//!   |  pure Rust, zero deps    |   |  PJRT CPU client                 |
//!   |  philox z-regeneration   |   |  AOT HLO artifacts from          |
//!   |  reference transformer   |   |  python/compile/aot.py (L2/L1)   |
//!   +--------------------------+   +----------------------------------+
//! ```
//!
//! - **L3 (this crate)**: the coordinator — layer selection
//!   ([`coordinator::selector`]), the SPSA/ZO engine ([`coordinator::spsa`]),
//!   the FO substrate ([`coordinator::fo`]), the trainer
//!   ([`coordinator::trainer`]), evaluation ([`eval`]) and the bench harness
//!   ([`bench`]) — all generic over the [`runtime::Backend`] trait.
//! - **Runtime**: [`runtime::native`] is a pure-Rust CPU backend (Philox
//!   Gaussian regeneration bit-compatible with the Pallas kernel, in-place
//!   allocation-free (masked) zo_axpy sweeps, blocked thread-parallel
//!   transformer kernels with a fused streaming LM head and native PEFT
//!   adapter forwards, plus the naive dense reference they are tested
//!   against — and a reference backward pass, so the FT baseline,
//!   pretraining, and every Table-4 PEFT cell are hermetic too). A
//!   software-bf16 twin of the forward path (`precision=bf16`, env
//!   `LEZO_PRECISION`) halves the streamed bytes, and absmax block-quantized
//!   int8/int4 shadows (`precision=int8|int4`, ~0.27x / ~0.14x of the f32
//!   forward bytes, kernels pinned bitwise to their f32 twins on the
//!   dequantized weights) cut them further — the trainable f32 masters stay
//!   authoritative in every mode ([`runtime::native`], "Precision").
//!   [`runtime::sharded`] runs N lockstep native replicas and fans each ZO
//!   step's forward evaluations across them — only `(probe, loss)` scalars
//!   travel, and the trajectory is bit-identical to single-backend native;
//!   with `shard_transport=socket` the replicas are separate `lezo worker`
//!   processes behind the framed, CRC-32'd, fault-tolerant wire protocol of
//!   [`runtime::transport`] (heartbeats, idempotent bounded retries, and
//!   degraded continuation that stays bitwise when a worker dies).
//!   [`runtime::pjrt`] (feature `pjrt`) executes the AOT HLO artifacts
//!   instead.
//! - **L2/L1** live in `python/compile/` and never run on the request path.
//!
//! ## Selecting a backend
//!
//! Config key `backend=auto|native|sharded|pjrt`; the `LEZO_BACKEND` env
//! var steers the `auto` default (an explicit config setting always wins).
//! `auto` uses PJRT when `<artifacts_root>/<model>/manifest.json` exists in
//! a pjrt-enabled build, else the native backend with the `<model>` preset.
//! `backend=sharded` takes a replica count from the `shards` key (env
//! `LEZO_SHARDS` wins, strict like `LEZO_THREADS`).
//!
//! ## Testing
//!
//! `cargo test -q` is hermetic: every algorithm invariant (perturb/flip/
//! restore identity, seed reproducibility, selector coverage, end-to-end
//! convergence) runs on the native backend with zero artifacts. Tests that
//! exercise the PJRT runtime are compiled only with `--features pjrt` and
//! skip (visibly, via [`require_artifacts!`]) unless AOT artifacts exist.
//!
//! The crate is `anyhow + xla` only — both vendored under `rust/vendor/`
//! for offline builds; everything else (JSON, RNG, stats, CLI parsing,
//! table rendering) is implemented in-repo.

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod model;
pub mod peft;
pub mod rng;
pub mod runtime;
pub mod stats;
pub mod tasks;
pub mod util;

/// Skip (with a visible note) a test that needs AOT artifacts.
///
/// Replaces the ad-hoc `if !have() { return }` early-outs: every
/// artifact-dependent test calls this first, so `cargo test -q` passes
/// hermetically and skipped tests announce themselves on stderr — and the
/// skip line names the exact `python -m compile.aot` invocation that
/// produces the missing artifact set, so it is directly actionable.
///
/// Default model is `opt-micro`; pass a model name to require another set.
/// `require_artifacts!("opt-micro", peft)` additionally requires the
/// adapter executables (a manifest with `lora_unit_len`): artifacts
/// exported with `--no-peft` skip those suites visibly instead of failing
/// inside an executable lookup.
#[macro_export]
macro_rules! require_artifacts {
    ($model:expr) => {
        if !$crate::runtime::backend::artifacts_available(
            &$crate::runtime::backend::default_artifact_dir($model),
        ) {
            eprintln!(
                "SKIPPED {}: requires AOT artifacts for '{}' — run \
                 `cd python && python -m compile.aot --sizes {}`, or point LEZO_ARTIFACTS \
                 at an artifact root",
                module_path!(),
                $model,
                $model
            );
            return;
        }
    };
    ($model:expr, peft) => {
        $crate::require_artifacts!($model);
        if !$crate::model::Manifest::load(&$crate::runtime::backend::default_artifact_dir(
            $model,
        ))
        .map(|m| m.lora_unit_len.is_some() && m.prefix_unit_len.is_some())
        .unwrap_or(false)
        {
            eprintln!(
                "SKIPPED {}: requires PEFT-enabled AOT artifacts for '{}' — re-export with \
                 `cd python && python -m compile.aot --sizes {}` (without --no-peft)",
                module_path!(),
                $model,
                $model
            );
            return;
        }
    };
    () => {
        $crate::require_artifacts!("opt-micro")
    };
}

//! # LeZO — layer-wise sparse, computation- and memory-efficient zeroth-order fine-tuning
//!
//! Rust + JAX + Pallas (three-layer, AOT via XLA/PJRT) reproduction of
//! *"Simultaneous Computation and Memory Efficient Zeroth-Order Optimizer for
//! Fine-Tuning Large Language Models"* (Wang et al., 2024).
//!
//! ## Layering
//!
//! ```text
//!   L3  coordinator (this crate): layer selection, SPSA/ZO-SGD engine,
//!       FO substrate, trainer, eval, bench harness
//!        |
//!        |  generic over runtime::backend::Backend
//!        v
//!   +--------------------------+   +----------------------------------+
//!   | NativeBackend            |   | PjrtBackend   (feature "pjrt")   |
//!   |  pure Rust, zero deps    |   |  PJRT CPU client                 |
//!   |  philox z-regeneration   |   |  AOT HLO artifacts from          |
//!   |  reference transformer   |   |  python/compile/aot.py (L2/L1)   |
//!   +--------------------------+   +----------------------------------+
//! ```
//!
//! - **L3 (this crate)**: the coordinator — layer selection
//!   ([`coordinator::selector`]), the SPSA/ZO engine ([`coordinator::spsa`]),
//!   the FO substrate ([`coordinator::fo`]), the trainer
//!   ([`coordinator::trainer`]), evaluation ([`eval`]) and the bench harness
//!   ([`bench`]) — all generic over the [`runtime::Backend`] trait.
//! - **Runtime**: [`runtime::native`] is a pure-Rust CPU backend (Philox
//!   Gaussian regeneration bit-compatible with the Pallas kernel, in-place
//!   allocation-free (masked) zo_axpy sweeps, blocked thread-parallel
//!   transformer kernels with a fused streaming LM head, plus the naive
//!   dense reference they are tested against — and a reference backward
//!   pass, so the FT baseline and pretraining are hermetic too).
//!   [`runtime::pjrt`] (feature `pjrt`) executes the AOT HLO artifacts
//!   instead.
//! - **L2/L1** live in `python/compile/` and never run on the request path.
//!
//! ## Selecting a backend
//!
//! Config key `backend=auto|native|pjrt`; the `LEZO_BACKEND` env var
//! steers the `auto` default (an explicit config setting always wins).
//! `auto` uses PJRT when `<artifacts_root>/<model>/manifest.json` exists in
//! a pjrt-enabled build, else the native backend with the `<model>` preset.
//!
//! ## Testing
//!
//! `cargo test -q` is hermetic: every algorithm invariant (perturb/flip/
//! restore identity, seed reproducibility, selector coverage, end-to-end
//! convergence) runs on the native backend with zero artifacts. Tests that
//! exercise the PJRT runtime are compiled only with `--features pjrt` and
//! skip (visibly, via [`require_artifacts!`]) unless AOT artifacts exist.
//!
//! The crate is `anyhow + xla` only — both vendored under `rust/vendor/`
//! for offline builds; everything else (JSON, RNG, stats, CLI parsing,
//! table rendering) is implemented in-repo.

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod model;
pub mod peft;
pub mod rng;
pub mod runtime;
pub mod stats;
pub mod tasks;
pub mod util;

/// Skip (with a visible note) a test that needs AOT artifacts.
///
/// Replaces the ad-hoc `if !have() { return }` early-outs: every
/// artifact-dependent test calls this first, so `cargo test -q` passes
/// hermetically and skipped tests announce themselves on stderr.
/// Default model is `opt-micro`; pass a model name to require another set.
#[macro_export]
macro_rules! require_artifacts {
    ($model:expr) => {
        if !$crate::runtime::backend::artifacts_available(
            &$crate::runtime::backend::default_artifact_dir($model),
        ) {
            eprintln!(
                "SKIPPED {}: requires AOT artifacts for '{}' (run `make artifacts` in python/, \
                 or point LEZO_ARTIFACTS at an artifact root)",
                module_path!(),
                $model
            );
            return;
        }
    };
    () => {
        $crate::require_artifacts!("opt-micro")
    };
}

// Smoke test: load a single-output (non-tuple) HLO produced by jax, run it
// via execute_b with device-resident buffers, and check determinism of the
// seeded-gaussian axpy (same seed -> same z).
use anyhow::Result;

fn main() -> Result<()> {
    let path = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "/tmp/notuple.hlo.txt".to_string());
    let client = xla::PjRtClient::cpu()?;
    println!("platform={}", client.platform_name());
    let proto = xla::HloModuleProto::from_text_file(&path)?;
    let exe = client.compile(&xla::XlaComputation::from_proto(&proto))?;

    let x: Vec<f32> = (0..8).map(|i| i as f32).collect();
    let xb = client.buffer_from_host_buffer(&x, &[8], None)?;
    let seed = client.buffer_from_host_buffer(&[42i32], &[], None)?;
    let c = client.buffer_from_host_buffer(&[0.5f32], &[], None)?;

    // x + 0.5 * z(seed=42)
    let out = exe.execute_b(&[&xb, &seed, &c])?;
    let buf = &out[0][0];
    let host = buf.to_literal_sync()?.to_vec::<f32>()?;
    println!("perturbed: {host:?}");

    // feed the output buffer straight back with coeff=-0.5 -> must recover x
    let cneg = client.buffer_from_host_buffer(&[-0.5f32], &[], None)?;
    let out2 = exe.execute_b(&[buf, &seed, &cneg])?;
    let host2 = out2[0][0].to_literal_sync()?.to_vec::<f32>()?;
    println!("restored:  {host2:?}");
    for (a, b) in host2.iter().zip(x.iter()) {
        assert!((a - b).abs() < 1e-5, "{a} != {b}");
    }
    println!("smoke OK");
    Ok(())
}

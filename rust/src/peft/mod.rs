//! PEFT parameter spaces for ZO fine-tuning (the paper's Table 4).
//!
//! With LoRA or prefix tuning, the ZO optimizer perturbs/updates only the
//! small per-block PEFT units; the frozen base units are still forward
//! arguments. LeZO's layer-wise sparsity then drops whole per-block PEFT
//! units, mirroring the paper's LeZO(LoRA)/LeZO(prefix) rows.
//!
//! The PEFT forward executables (forward_loss_lora_s*, ...) are exported by
//! `python -m compile.aot --peft`; their argument order is
//! [base units..., peft units (one per block)..., tokens, targets, mask].

use anyhow::{bail, Result};
use std::fmt;
use std::str::FromStr;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PeftMode {
    /// Full-parameter fine-tuning (the default LeZO setting).
    Full,
    /// LoRA adapters on Wq and Wv (rank r = 8, alpha = 16 as in the paper).
    Lora,
    /// Prefix tuning: 5 virtual KV positions per layer.
    Prefix,
}

impl FromStr for PeftMode {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "full" | "none" => PeftMode::Full,
            "lora" => PeftMode::Lora,
            "prefix" => PeftMode::Prefix,
            _ => bail!("unknown peft mode '{s}' (full|lora|prefix)"),
        })
    }
}

impl fmt::Display for PeftMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            PeftMode::Full => "full",
            PeftMode::Lora => "lora",
            PeftMode::Prefix => "prefix",
        };
        write!(f, "{s}")
    }
}

/// LoRA dimensions used by the aot exporter (kept in sync with aot.py).
pub const LORA_RANK: usize = 8;
pub const LORA_ALPHA: f64 = 16.0;
pub const PREFIX_TOKENS: usize = 5;

/// Flat length of one per-block LoRA unit: A_q (d x r) + B_q (r x d) +
/// A_v + B_v.
pub fn lora_unit_len(d_model: usize) -> usize {
    4 * d_model * LORA_RANK
}

/// Flat length of one per-block prefix unit: K and V prefixes, each
/// (PREFIX_TOKENS x d_model).
pub fn prefix_unit_len(d_model: usize) -> usize {
    2 * PREFIX_TOKENS * d_model
}

/// Host-side init of PEFT units (mirrors aot.py's peft_init): LoRA A is
/// N(0, 0.02), B zero (so the initial delta is exactly zero); prefixes are
/// N(0, 0.02).
pub fn init_peft_units(
    mode: PeftMode,
    n_layers: usize,
    d_model: usize,
    seed: u64,
) -> Vec<Vec<f32>> {
    let mut rng = crate::rng::Rng::new(crate::rng::derive(seed, crate::rng::purpose::INIT, 77));
    match mode {
        PeftMode::Full => vec![],
        PeftMode::Lora => (0..n_layers)
            .map(|_| {
                let half = 2 * d_model * LORA_RANK; // A_q then B_q then A_v then B_v
                let mut u = Vec::with_capacity(lora_unit_len(d_model));
                // A_q
                for _ in 0..d_model * LORA_RANK {
                    u.push((rng.gaussian() * 0.02) as f32);
                }
                // B_q = 0
                u.resize(half, 0.0);
                // A_v
                for _ in 0..d_model * LORA_RANK {
                    u.push((rng.gaussian() * 0.02) as f32);
                }
                // B_v = 0
                u.resize(lora_unit_len(d_model), 0.0);
                u
            })
            .collect(),
        PeftMode::Prefix => (0..n_layers)
            .map(|_| {
                (0..prefix_unit_len(d_model)).map(|_| (rng.gaussian() * 0.02) as f32).collect()
            })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_display_round_trip() {
        for s in ["full", "lora", "prefix"] {
            let m: PeftMode = s.parse().unwrap();
            assert_eq!(m.to_string(), s);
        }
        assert!("adapterx".parse::<PeftMode>().is_err());
    }

    #[test]
    fn unit_lens_match_exporter_contract() {
        assert_eq!(lora_unit_len(64), 4 * 64 * 8);
        assert_eq!(prefix_unit_len(64), 2 * 5 * 64);
    }

    #[test]
    fn lora_init_delta_is_zero() {
        let units = init_peft_units(PeftMode::Lora, 4, 64, 0);
        assert_eq!(units.len(), 4);
        for u in &units {
            assert_eq!(u.len(), lora_unit_len(64));
            // B_q block (second quarter) and B_v block (fourth quarter) zero
            let q = u.len() / 4;
            assert!(u[q..2 * q].iter().all(|&x| x == 0.0));
            assert!(u[3 * q..].iter().all(|&x| x == 0.0));
            // A blocks non-zero
            assert!(u[..q].iter().any(|&x| x != 0.0));
        }
    }

    #[test]
    fn prefix_init_shape_and_scale() {
        let units = init_peft_units(PeftMode::Prefix, 6, 128, 1);
        assert_eq!(units.len(), 6);
        for u in &units {
            assert_eq!(u.len(), prefix_unit_len(128));
            let std = {
                let m: f32 = u.iter().sum::<f32>() / u.len() as f32;
                (u.iter().map(|x| (x - m) * (x - m)).sum::<f32>() / u.len() as f32).sqrt()
            };
            assert!((std - 0.02).abs() < 0.01, "std={std}");
        }
    }

    #[test]
    fn full_mode_has_no_units() {
        assert!(init_peft_units(PeftMode::Full, 4, 64, 0).is_empty());
    }

    #[test]
    fn init_is_deterministic() {
        let a = init_peft_units(PeftMode::Prefix, 2, 64, 5);
        let b = init_peft_units(PeftMode::Prefix, 2, 64, 5);
        assert_eq!(a, b);
    }
}

//! PEFT parameter spaces for ZO fine-tuning (the paper's Table 4).
//!
//! With LoRA or prefix tuning, the ZO optimizer perturbs/updates only the
//! small per-block PEFT units; the frozen base units are still forward
//! arguments. LeZO's layer-wise sparsity then drops whole per-block PEFT
//! units, mirroring the paper's LeZO(LoRA)/LeZO(prefix) rows.
//!
//! Both backends consume the same flat per-block adapter layout (kept in
//! sync with `python/compile/peft.py`; see ARCHITECTURE.md):
//!
//! ```text
//!   LoRA unit   = [A_q (D,R) | B_q (R,D) | A_v (D,R) | B_v (R,D)]  (4*D*R)
//!   prefix unit = [K_pre (P,D) | V_pre (P,D)]                      (2*P*D)
//! ```
//!
//! and the same forward-argument order: [base units..., peft units (one
//! per block)..., tokens, targets, mask]. On PJRT the adapter families
//! (`forward_loss_lora_s*`, ...) are AOT-exported by
//! `python -m compile.aot`; on the native backend the adapters fold into
//! the blocked attention kernels (`runtime/native/kernels.rs`) — the dense
//! `W + (alpha/r) B·A` delta is never materialized on either path.

use anyhow::{bail, Result};
use std::fmt;
use std::str::FromStr;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PeftMode {
    /// Full-parameter fine-tuning (the default LeZO setting).
    Full,
    /// LoRA adapters on Wq and Wv (rank r = 8, alpha = 16 as in the paper).
    Lora,
    /// Prefix tuning: 5 virtual KV positions per layer.
    Prefix,
}

impl FromStr for PeftMode {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "full" | "none" => PeftMode::Full,
            "lora" => PeftMode::Lora,
            "prefix" => PeftMode::Prefix,
            _ => bail!("unknown peft mode '{s}' (full|lora|prefix)"),
        })
    }
}

impl fmt::Display for PeftMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            PeftMode::Full => "full",
            PeftMode::Lora => "lora",
            PeftMode::Prefix => "prefix",
        };
        write!(f, "{s}")
    }
}

/// LoRA dimensions used by the aot exporter (kept in sync with aot.py).
pub const LORA_RANK: usize = 8;
pub const LORA_ALPHA: f64 = 16.0;
pub const PREFIX_TOKENS: usize = 5;

/// Flat length of one per-block LoRA unit: A_q (d x r) + B_q (r x d) +
/// A_v + B_v.
pub fn lora_unit_len(d_model: usize) -> usize {
    4 * d_model * LORA_RANK
}

/// Flat length of one per-block prefix unit: K and V prefixes, each
/// (PREFIX_TOKENS x d_model).
pub fn prefix_unit_len(d_model: usize) -> usize {
    2 * PREFIX_TOKENS * d_model
}

/// Split one flat LoRA unit into its four row-major matrices
/// `(A_q (D,R), B_q (R,D), A_v (D,R), B_v (R,D))` — the layout the aot
/// exporter writes and the native kernels consume.
pub fn split_lora(unit: &[f32], d_model: usize) -> (&[f32], &[f32], &[f32], &[f32]) {
    debug_assert_eq!(unit.len(), lora_unit_len(d_model));
    let q = d_model * LORA_RANK;
    (&unit[..q], &unit[q..2 * q], &unit[2 * q..3 * q], &unit[3 * q..])
}

/// Split one flat prefix unit into `(K_pre (P,D), V_pre (P,D))`.
pub fn split_prefix(unit: &[f32], d_model: usize) -> (&[f32], &[f32]) {
    debug_assert_eq!(unit.len(), prefix_unit_len(d_model));
    let half = PREFIX_TOKENS * d_model;
    (&unit[..half], &unit[half..])
}

/// Host-side init of PEFT units (mirrors aot.py's peft_init): LoRA A is
/// N(0, 0.02), B zero (so the initial delta is exactly zero); prefixes are
/// N(0, 0.02).
pub fn init_peft_units(
    mode: PeftMode,
    n_layers: usize,
    d_model: usize,
    seed: u64,
) -> Vec<Vec<f32>> {
    let mut rng = crate::rng::Rng::new(crate::rng::derive(seed, crate::rng::purpose::INIT, 77));
    match mode {
        PeftMode::Full => vec![],
        PeftMode::Lora => (0..n_layers)
            .map(|_| {
                let half = 2 * d_model * LORA_RANK; // A_q then B_q then A_v then B_v
                let mut u = Vec::with_capacity(lora_unit_len(d_model));
                // A_q
                for _ in 0..d_model * LORA_RANK {
                    u.push((rng.gaussian() * 0.02) as f32);
                }
                // B_q = 0
                u.resize(half, 0.0);
                // A_v
                for _ in 0..d_model * LORA_RANK {
                    u.push((rng.gaussian() * 0.02) as f32);
                }
                // B_v = 0
                u.resize(lora_unit_len(d_model), 0.0);
                u
            })
            .collect(),
        PeftMode::Prefix => (0..n_layers)
            .map(|_| {
                (0..prefix_unit_len(d_model)).map(|_| (rng.gaussian() * 0.02) as f32).collect()
            })
            .collect(),
    }
}

/// [`init_peft_units`] with the LoRA B blocks re-randomized to N(0, 0.05)
/// instead of zero. Test support: the standard init zeroes B so step 0 is
/// exactly the base model — which also makes the delta path dead, so tests
/// that pin the LoRA math (fused-vs-dense, FD checks) start from this
/// non-degenerate variant. Prefix units are unchanged (their init is
/// already non-zero).
pub fn init_peft_units_nonzero_b(
    mode: PeftMode,
    n_layers: usize,
    d_model: usize,
    seed: u64,
) -> Vec<Vec<f32>> {
    let mut units = init_peft_units(mode, n_layers, d_model, seed);
    if mode == PeftMode::Lora {
        let mut rng =
            crate::rng::Rng::new(crate::rng::derive(seed, crate::rng::purpose::INIT, 78));
        let q = d_model * LORA_RANK;
        for u in units.iter_mut() {
            for x in u[q..2 * q].iter_mut() {
                *x = (rng.gaussian() * 0.05) as f32;
            }
            for x in u[3 * q..4 * q].iter_mut() {
                *x = (rng.gaussian() * 0.05) as f32;
            }
        }
    }
    units
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_display_round_trip() {
        for s in ["full", "lora", "prefix"] {
            let m: PeftMode = s.parse().unwrap();
            assert_eq!(m.to_string(), s);
        }
        assert!("adapterx".parse::<PeftMode>().is_err());
    }

    #[test]
    fn unit_lens_match_exporter_contract() {
        assert_eq!(lora_unit_len(64), 4 * 64 * 8);
        assert_eq!(prefix_unit_len(64), 2 * 5 * 64);
    }

    #[test]
    fn unit_lens_match_backend_cross_check_for_every_preset() {
        // Property over all ModelSpec presets: the formula here, the
        // Backend::peft_unit_len cross-check path, and the init'd unit
        // shapes all agree — the same numbers the PJRT backend validates
        // against its manifest's lora_unit_len/prefix_unit_len.
        use crate::runtime::backend::Backend;
        for name in ["opt-nano", "opt-micro", "opt-tiny", "opt-small", "opt-base"] {
            let b = crate::runtime::NativeBackend::preset(name).unwrap();
            let spec = b.spec().clone();
            for (mode, want) in [
                (PeftMode::Full, 0),
                (PeftMode::Lora, 4 * spec.d_model * LORA_RANK),
                (PeftMode::Prefix, 2 * PREFIX_TOKENS * spec.d_model),
            ] {
                assert_eq!(b.peft_unit_len(mode).unwrap(), want, "{name} {mode}");
                let units = init_peft_units(mode, spec.n_layers, spec.d_model, 1);
                let n_units = if mode == PeftMode::Full { 0 } else { spec.n_layers };
                assert_eq!(units.len(), n_units, "{name} {mode}");
                for u in &units {
                    assert_eq!(u.len(), want, "{name} {mode}");
                }
                assert!(b.supports_peft(mode), "{name} {mode}");
            }
        }
    }

    #[test]
    fn split_helpers_partition_the_flat_unit() {
        let d = 32;
        let unit: Vec<f32> = (0..lora_unit_len(d)).map(|i| i as f32).collect();
        let (a_q, b_q, a_v, b_v) = split_lora(&unit, d);
        let q = d * LORA_RANK;
        assert_eq!((a_q.len(), b_q.len(), a_v.len(), b_v.len()), (q, q, q, q));
        assert_eq!(a_q[0], 0.0);
        assert_eq!(b_q[0], q as f32);
        assert_eq!(b_v[q - 1], (4 * q - 1) as f32);

        let unit: Vec<f32> = (0..prefix_unit_len(d)).map(|i| i as f32).collect();
        let (k_pre, v_pre) = split_prefix(&unit, d);
        assert_eq!(k_pre.len(), PREFIX_TOKENS * d);
        assert_eq!(v_pre.len(), PREFIX_TOKENS * d);
        assert_eq!(v_pre[0], (PREFIX_TOKENS * d) as f32);
    }

    #[test]
    fn lora_init_delta_is_zero() {
        let units = init_peft_units(PeftMode::Lora, 4, 64, 0);
        assert_eq!(units.len(), 4);
        for u in &units {
            assert_eq!(u.len(), lora_unit_len(64));
            // B_q block (second quarter) and B_v block (fourth quarter) zero
            let q = u.len() / 4;
            assert!(u[q..2 * q].iter().all(|&x| x == 0.0));
            assert!(u[3 * q..].iter().all(|&x| x == 0.0));
            // A blocks non-zero
            assert!(u[..q].iter().any(|&x| x != 0.0));
        }
    }

    #[test]
    fn prefix_init_shape_and_scale() {
        let units = init_peft_units(PeftMode::Prefix, 6, 128, 1);
        assert_eq!(units.len(), 6);
        for u in &units {
            assert_eq!(u.len(), prefix_unit_len(128));
            let std = {
                let m: f32 = u.iter().sum::<f32>() / u.len() as f32;
                (u.iter().map(|x| (x - m) * (x - m)).sum::<f32>() / u.len() as f32).sqrt()
            };
            assert!((std - 0.02).abs() < 0.01, "std={std}");
        }
    }

    #[test]
    fn full_mode_has_no_units() {
        assert!(init_peft_units(PeftMode::Full, 4, 64, 0).is_empty());
    }

    #[test]
    fn init_is_deterministic() {
        let a = init_peft_units(PeftMode::Prefix, 2, 64, 5);
        let b = init_peft_units(PeftMode::Prefix, 2, 64, 5);
        assert_eq!(a, b);
    }
}

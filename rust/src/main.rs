//! `lezo` — the launcher CLI (DESIGN.md S17).
//!
//! ```text
//! lezo train   [--config FILE] [key=value ...]   run one fine-tuning run
//! lezo pretrain model=<size> [steps=N lr=X seed=S]
//! lezo bench   <id|all> [key=value ...]          regenerate a paper table/figure
//! lezo worker  --listen <addr>                   serve as a socket-transport shard
//! lezo info    [model=<size>]                    show artifact manifest
//! lezo render  task=<name> [n=K seed=S]          dump synthetic examples
//! ```
//!
//! Offline constraint: no clap; overrides are `key=value` tokens parsed by
//! `RunConfig::set` plus a few global flags (`-q`, `-v`, `--config`).

use anyhow::{bail, Context, Result};
use lezo::config::RunConfig;
use lezo::coordinator::Trainer;
use lezo::bench;

fn usage() -> ! {
    eprintln!(
        "lezo — layer-wise sparse zeroth-order fine-tuning\n\n\
         USAGE:\n  lezo train   [--config FILE] [key=value ...]\n  \
         lezo pretrain model=<size> [backend=auto|native|pjrt] [steps=N] [lr=X] [seed=S]\n  \
         lezo bench   <id|all> [key=value ...]    ids: {}\n  \
         lezo worker  --listen <addr>             serve as a socket-transport shard\n  \
         lezo info    [model=<size>]\n  lezo render  task=<name> [n=K] [seed=S]\n\n\
         Common keys: model backend shards shard_transport workers task method peft\n\
         drop_layers lr mu steps eval_every eval_examples train_examples seed\n\
         icl_shots mean_len checkpoint precision threads zo_opt save_every resume\n\
         faults on_nonfinite divergence_factor net_timeout_ms net_retries\n\
         (backend:   auto|native|sharded|pjrt — native needs no artifacts;\n\
          sharded runs N native replicas in lockstep and fans each ZO step's\n\
          forwards across them, bit-identical to native)\n\
         (shards:    replica count for backend=sharded (default 2).\n\
          Env LEZO_SHARDS overrides, like LEZO_THREADS for threads)\n\
         (shard_transport: thread|socket — socket fans evals out to remote\n\
          `lezo worker` processes listed in workers=host:port,... (one per\n\
          shard), bit-identical to thread/native; workers that die mid-run\n\
          are dropped and the run continues on the survivors)\n\
         (net_timeout_ms / net_retries: per-request socket timeout and\n\
          bounded attempt count; env LEZO_NET_TIMEOUT_MS / LEZO_NET_RETRIES\n\
          override, like LEZO_THREADS for threads)\n\
         (method:    zero-shot|icl|ft|mezo|lezo|smezo, or a Table-4 alias\n\
          mezo-lora|lezo-lora|mezo-prefix|lezo-prefix that also sets peft)\n\
         (peft:      full|lora|prefix — adapter tuning runs on any backend)\n\
         (precision: f32|bf16|int8|int4 — bf16 runs the native forward over\n\
          half-width shadows (half the streamed bytes); int8/int4 stream\n\
          absmax block-quantized weight shadows (~0.27x/~0.14x the bytes,\n\
          activations stay f32); f32 masters stay authoritative.\n\
          Env LEZO_PRECISION overrides, like LEZO_THREADS for threads)\n\
         (zo_opt:    zo-sgd|zo-sgd-momentum|zo-adam|zo-sign-sgd|fzoo — the ZO\n\
          update rule; momentum/adam replay past directions from seeds.\n\
          Env LEZO_ZO_OPT overrides, like LEZO_PRECISION)\n\
         (save_every: N>0 writes train_state.ckpt atomically every N steps\n\
          (0 = off); resume: auto|never|<path> — auto picks up the run's own\n\
          state after a crash, bit-identical to the uninterrupted run)\n\
         (faults:    deterministic fault injection for crash + transport\n\
          drills, e.g. nan-loss@120,crash@250,io-err@save:2 or socket-mode\n\
          net-drop@K, net-delay@K:ms, net-corrupt@K, worker-crash@K:shard\n\
          (injected worker-side); env LEZO_FAULTS overrides)\n\
         (on_nonfinite: error|skip-step — what a NaN/inf training loss does;\n\
          divergence_factor: halt when smoothed loss exceeds this multiple\n\
          of the start loss, 0 = off)\n\
         Flags: -q quiet, -v verbose",
        bench::ALL_BENCHES.join(" ")
    );
    std::process::exit(2);
}

fn split_flags(args: &[String]) -> (Vec<String>, Option<String>) {
    let mut rest = Vec::new();
    let mut config_file = None;
    let mut it = args.iter().peekable();
    while let Some(a) = it.next() {
        match a.as_str() {
            "-q" => lezo::util::set_log_level(0),
            "-v" => lezo::util::set_log_level(2),
            "--config" => {
                config_file = it.next().cloned();
                if config_file.is_none() {
                    eprintln!("--config needs a file");
                    usage();
                }
            }
            _ => rest.push(a.clone()),
        }
    }
    (rest, config_file)
}

fn cmd_train(args: &[String]) -> Result<()> {
    let (overrides, config_file) = split_flags(args);
    let mut cfg = match config_file {
        Some(f) => RunConfig::from_file(&f)?,
        None => RunConfig::default(),
    };
    cfg.apply_overrides(&overrides)?;
    let report = Trainer::new(cfg).run()?;
    println!("task           : {}", report.task);
    println!("method         : {}", report.method);
    println!("backend        : {}", report.backend);
    println!("precision      : {}", report.precision);
    if let Some(k) = report.resumed_from {
        println!("resumed from   : step {k}");
    }
    if matches!(
        report.method,
        lezo::config::Method::Mezo | lezo::config::Method::Lezo | lezo::config::Method::Smezo
    ) {
        println!("zo opt         : {}", report.zo_opt);
        if report.zo_state_bytes > 0 {
            println!("zo opt state   : {} B (seed-replay history)", report.zo_state_bytes);
        }
    }
    println!("final {:>3}      : {:.1}%", report.metric_kind, 100.0 * report.final_metric);
    println!("best  {:>3}      : {:.1}%", report.metric_kind, 100.0 * report.best_metric);
    println!("train time     : {:.1}s", report.train_secs);
    if report.stage_times.steps > 0 {
        let (p, f, u, o) = report.stage_times.per_step_ms();
        println!(
            "per-step       : {:.1} ms (perturb {p:.1} / forward {f:.1} / update {u:.1} / other {o:.1})",
            p + f + u + o
        );
        println!("non-forward    : {:.0}%", 100.0 * report.stage_times.non_forward_fraction());
        if report.stage_times.rt_secs > 0.0 {
            println!("socket rt      : {:.1} ms/step", report.stage_times.per_step_rt_ms());
        }
        println!("active params  : {:.0}%/step", 100.0 * report.active_param_fraction);
    }
    println!("\nconvergence (step, train_s, {}%):", report.metric_kind);
    for p in &report.history {
        println!("  {:>6}  {:>8.1}s  {:>5.1}", p.step, p.train_secs, 100.0 * p.metric);
    }
    Ok(())
}

fn cmd_pretrain(args: &[String]) -> Result<()> {
    use lezo::coordinator::trainer;
    let (overrides, _) = split_flags(args);
    let mut cfg = RunConfig::default();
    let mut steps = 300usize;
    let mut lr = 1e-3f64;
    let mut seed = 0u64;
    let mut log_every = 50usize;
    for ov in &overrides {
        let (k, v) = ov.split_once('=').with_context(|| format!("'{ov}' is not key=value"))?;
        match k {
            "model" | "artifacts" | "artifacts_root" | "backend" | "threads" => {
                cfg.set(k, v)?
            }
            "steps" => steps = v.parse()?,
            "lr" => lr = v.parse()?,
            "seed" => seed = v.parse()?,
            "log_every" => log_every = v.parse()?,
            _ => bail!("unknown pretrain key '{k}'"),
        }
    }
    let dir = std::path::PathBuf::from(cfg.artifact_dir());
    let (first, last) = trainer::pretrain(&cfg, steps, lr, seed, log_every)?;
    println!("pretrained {}: LM loss {first:.3} -> {last:.3} over {steps} steps", cfg.model);
    println!("checkpoint: {}", dir.join("pretrained.ckpt").display());
    Ok(())
}

fn cmd_worker(args: &[String]) -> Result<()> {
    let (rest, _) = split_flags(args);
    let mut listen = None;
    let mut it = rest.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--listen" => {
                listen = it.next().cloned();
                if listen.is_none() {
                    bail!("--listen needs a host:port address (e.g. --listen 127.0.0.1:7001)");
                }
            }
            other => bail!("unknown worker arg '{other}' (usage: lezo worker --listen <addr>)"),
        }
    }
    let Some(addr) = listen else {
        bail!("lezo worker needs --listen <addr> (e.g. --listen 127.0.0.1:7001, or :0 for an ephemeral port)");
    };
    lezo::runtime::transport::run_worker(&addr)
}

fn cmd_bench(args: &[String]) -> Result<()> {
    let (rest, _) = split_flags(args);
    let Some((id, overrides)) = rest.split_first() else { usage() };
    bench::run_bench(id, overrides)
}

fn cmd_info(args: &[String]) -> Result<()> {
    let (overrides, _) = split_flags(args);
    let mut cfg = RunConfig::default();
    cfg.apply_overrides(&overrides)?;
    let dir = std::path::PathBuf::from(cfg.artifact_dir());
    // one shared summary for both sources: manifest when exported, preset
    // otherwise (same rule as the trainer and bench harness)
    let (s, manifest) = lezo::runtime::backend::resolve_model(&cfg.model, &dir)?;
    let origin = if manifest.is_some() { "AOT artifacts" } else { "native preset; no AOT artifacts" };
    println!("model       : {} ({origin})", s.name);
    println!("params      : {} ({} units)", s.param_count(), s.n_units());
    println!(
        "dims        : d_model={} layers={} heads={} vocab={}",
        s.d_model, s.n_layers, s.n_heads, s.vocab
    );
    println!("seq buckets : {:?} (max {})", s.seq_buckets, s.max_seq);
    println!("batch       : train={} eval={}", s.train_batch, s.eval_batch);
    println!("units:");
    for (name, len) in s.unit_names().iter().zip(s.unit_lens()) {
        println!("  {name:<12} {len:>10}");
    }
    match &manifest {
        Some(m) => {
            println!("pallas fwd  : {}", m.use_pallas_forward);
            if let Some(l) = m.lora_unit_len {
                println!("lora unit   : {l}");
            }
            if let Some(l) = m.prefix_unit_len {
                println!("prefix unit : {l}");
            }
            let pretrained = m.dir.join("pretrained.ckpt");
            println!(
                "pretrained  : {}",
                if pretrained.exists() { "yes" } else { "no (runs start from params_init.bin)" }
            );
        }
        None => println!("backend     : native (run `make artifacts` in python/ for pjrt)"),
    }
    Ok(())
}

fn cmd_render(args: &[String]) -> Result<()> {
    let (overrides, _) = split_flags(args);
    let mut task_name = "sst2".to_string();
    let mut n = 5usize;
    let mut seed = 0u64;
    let mut mean_len = 24usize;
    for ov in &overrides {
        let (k, v) = ov.split_once('=').with_context(|| format!("'{ov}' is not key=value"))?;
        match k {
            "task" => task_name = v.into(),
            "n" => n = v.parse()?,
            "seed" => seed = v.parse()?,
            "mean_len" => mean_len = v.parse()?,
            _ => bail!("unknown render key '{k}'"),
        }
    }
    let task = lezo::tasks::make_task(&task_name)?;
    let examples = lezo::tasks::eval_set(task.as_ref(), seed, n, mean_len);
    for (i, ex) in examples.iter().enumerate() {
        println!("--- {task_name} #{i}");
        println!("prompt : {}", lezo::data::vocab::render_seq(&ex.prompt));
        if ex.options.is_empty() {
            println!("answer : {}", lezo::data::vocab::render_seq(&ex.answer));
        } else {
            for (oi, opt) in ex.options.iter().enumerate() {
                let mark = if oi == ex.gold { "*" } else { " " };
                println!("opt {oi}{mark} : {}", lezo::data::vocab::render_seq(opt));
            }
        }
    }
    Ok(())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = args.split_first() else { usage() };
    let result = match cmd.as_str() {
        "train" => cmd_train(rest),
        "pretrain" => cmd_pretrain(rest),
        "bench" => cmd_bench(rest),
        "worker" => cmd_worker(rest),
        "info" => cmd_info(rest),
        "render" => cmd_render(rest),
        "help" | "--help" | "-h" => usage(),
        _ => {
            eprintln!("unknown command '{cmd}'");
            usage()
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

//! Offline stand-in for the `anyhow` crate, vendored so the build needs no
//! network access. Implements the API subset this repository uses:
//!
//! - [`Error`] / [`Result`] with context chains
//! - [`anyhow!`], [`bail!`], [`ensure!`]
//! - the [`Context`] extension trait on `Result` and `Option`
//! - blanket `From<E: std::error::Error>` so `?` converts std errors
//!
//! Formatting matches anyhow's conventions: `{}` prints the outermost
//! message, `{:#}` prints the whole chain joined with `: `.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error with a chain of context messages (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context/cause messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    fn from_std<E: StdError + ?Sized>(err: &E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut src = err.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        for cause in self.chain.iter().skip(1) {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        Error::from_std(&err)
    }
}

mod ext {
    use super::*;

    /// Internal: anything convertible into an [`Error`]. Mirrors anyhow's
    /// `ext::StdError` trick so `Context` works on both `Result<T, E>` with
    /// `E: std::error::Error` and `Result<T, anyhow::Error>`.
    pub trait IntoError {
        fn into_error(self) -> Error;
    }

    impl<E: StdError + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> Error {
            Error::from_std(&self)
        }
    }

    impl IntoError for Error {
        fn into_error(self) -> Error {
            self
        }
    }
}

/// Extension trait adding context to `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: ext::IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_outermost_alternate_chain() {
        let e: Error = io_err().into();
        let e = e.context("reading config");
        assert_eq!(e.to_string(), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing file");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<i32> {
            let n: i32 = "12".parse()?;
            Ok(n)
        }
        assert_eq!(f().unwrap(), 12);
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: missing file");

        let o: Option<i32> = None;
        let e = o.with_context(|| format!("missing {}", "value")).unwrap_err();
        assert_eq!(e.to_string(), "missing value");
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner {}", 7));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner 7");
    }

    #[test]
    fn ensure_and_bail() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("too big");
            }
            Ok(x)
        }
        assert!(f(5).is_ok());
        assert!(f(-1).unwrap_err().to_string().contains("positive"));
        assert_eq!(f(200).unwrap_err().to_string(), "too big");
    }

    #[test]
    fn bare_ensure() {
        fn f(x: i32) -> Result<()> {
            ensure!(x == 1);
            Ok(())
        }
        assert!(f(2).unwrap_err().to_string().contains("condition failed"));
    }
}

//! Compile-time stub of the `xla` (xla-rs) PJRT binding.
//!
//! The real binding links the PJRT CPU plugin and is only available in
//! environments that ship it. This stub exposes the same API surface the
//! `lezo` crate uses so `cargo build --features pjrt` type-checks anywhere;
//! every entry point fails at *runtime* with a clear message. To run the
//! real PJRT backend, point Cargo at an actual xla-rs checkout:
//!
//! ```toml
//! [patch."crates-io"]            # or replace rust/vendor/xla wholesale
//! xla = { path = "/path/to/xla-rs" }
//! ```
//!
//! The default build does not enable the `pjrt` feature, so this crate is
//! normally not compiled at all; the hermetic test suite runs on the
//! pure-Rust native backend instead.

use std::fmt;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT is unavailable in this build — the `xla` dependency is the in-repo \
         compile-time stub. Link a real xla-rs checkout (see rust/vendor/xla/src/lib.rs) \
         or use the native backend (LEZO_BACKEND=native)."
    )))
}

/// Element types transferable to/from device buffers.
pub trait NativeType: Copy + 'static {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}
impl NativeType for u8 {}

pub struct PjRtClient {
    _private: (),
}

pub struct PjRtBuffer {
    _private: (),
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

pub struct HloModuleProto {
    _private: (),
}

pub struct XlaComputation {
    _private: (),
}

pub struct Literal {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

impl Literal {
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        unavailable("Literal::get_first_element")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

//! Sparsity sweep: how the dropout number (the paper's core knob) trades
//! per-step cost against accuracy — a miniature of Figs. 3 and 4.
//!
//! ```bash
//! cargo run --release --example sparsity_sweep [model] [steps]
//! ```

use anyhow::Result;
use lezo::config::{Method, RunConfig};
use lezo::coordinator::Trainer;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().cloned().unwrap_or_else(|| "opt-micro".into());
    let steps: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(400);

    // artifact manifest when exported, else the native preset — the sweep
    // runs end-to-end on the pure-Rust backend with zero artifacts
    let mut probe = RunConfig::default();
    probe.model = model.clone();
    let spec = lezo::bench::model_spec_for(&probe)?;
    let nl = spec.n_layers;
    println!("{model}: {} params, {nl} blocks, sweeping drop = 0..={nl}", spec.param_count());
    println!(
        "\n{:>6} {:>8} {:>10} {:>10} {:>10} {:>8}",
        "drop", "rho", "active%", "ms/step", "saved%", "best%"
    );

    let mut base_ms = 0.0f64;
    for drop in 0..=nl {
        let mut cfg = RunConfig::default();
        cfg.model = model.clone();
        cfg.task = "sst2".into();
        cfg.method = if drop == 0 { Method::Mezo } else { Method::Lezo };
        cfg.drop_layers = drop;
        cfg.steps = steps;
        cfg.eval_every = steps;
        cfg.eval_examples = 60;
        // larger LR under heavier sparsity (Fig. 3's diagonal)
        cfg.lr = 1e-4 * (1.0 + 2.0 * drop as f64 / nl as f64);
        let r = Trainer::new(cfg).run()?;
        if drop == 0 {
            base_ms = r.per_step_ms();
        }
        println!(
            "{:>6} {:>8.2} {:>9.0}% {:>10.1} {:>9.0}% {:>8.1}",
            format!("{drop}/{nl}"),
            drop as f64 / nl as f64,
            100.0 * r.active_param_fraction,
            r.per_step_ms(),
            100.0 * (1.0 - r.per_step_ms() / base_ms),
            100.0 * r.best_metric,
        );
    }
    println!("\nthe last row tunes only embedding+head — the paper's rho=1 collapse.");
    Ok(())
}

//! Quickstart: fine-tune a pretrained model on a synthetic SST-2-like task
//! with LeZO and compare against MeZO at the same step budget.
//!
//! ```bash
//! make artifacts                                  # once
//! cargo run --release --example quickstart        # a couple of minutes on CPU
//! ```

use anyhow::Result;
use lezo::config::{Method, RunConfig};
use lezo::coordinator::Trainer;

fn main() -> Result<()> {
    // 1. Configure a run. `opt-micro` is the test-scale model; swap in
    //    opt-tiny/opt-small/opt-base for the paper-shaped experiments.
    let mut cfg = RunConfig::default();
    cfg.model = "opt-micro".into();
    cfg.task = "sst2".into();
    cfg.steps = 800;
    cfg.eval_every = 200;
    cfg.eval_examples = 100;
    cfg.mu = 1e-3;

    // 2. MeZO baseline: full-parameter ZO (drop_layers = 0).
    let mut mezo = cfg.clone();
    mezo.method = Method::Mezo;
    mezo.lr = 1e-4;
    println!("== MeZO (full-parameter ZO) ==");
    let rm = Trainer::new(mezo).run()?;

    // 3. LeZO: drop 75% of the transformer blocks each step. Over steps the
    //    random per-step selection still covers every layer (full-parameter
    //    fine-tuning), but each step does a fraction of the perturb/update
    //    work — the paper's contribution.
    let mut lezo = cfg.clone();
    lezo.method = Method::Lezo;
    lezo.drop_layers = 3; // of opt-micro's 4 blocks
    lezo.lr = 2.5e-4; // sparser steps tolerate (need) larger LRs — Fig. 3
    println!("== LeZO (75% of blocks dropped per step) ==");
    let rl = Trainer::new(lezo).run()?;

    // 4. Compare.
    println!("\n{:<26}{:>10}{:>12}{:>12}", "", "best acc", "ms/step", "train s");
    for (name, r) in [("MeZO", &rm), ("LeZO (drop 3/4)", &rl)] {
        println!(
            "{:<26}{:>9.1}%{:>12.1}{:>12.1}",
            name,
            100.0 * r.best_metric,
            r.per_step_ms(),
            r.train_secs
        );
    }
    println!(
        "\ncomputation speedup: {:.2}x (paper Fig. 5; grows with model depth and sparsity)",
        rm.per_step_ms() / rl.per_step_ms()
    );
    let (p, f, u, o) = rm.stage_times.per_step_ms();
    println!(
        "MeZO stage split: perturb {:.0}% / forward {:.0}% / update {:.0}% — the paper's\n\
         Fig. 2 observation that non-forward work dominates a ZO step.",
        100.0 * p / (p + f + u + o),
        100.0 * f / (p + f + u + o),
        100.0 * u / (p + f + u + o),
    );
    Ok(())
}

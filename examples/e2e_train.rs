//! End-to-end driver (the DESIGN.md validation run): pretrain the ~100M
//! parameter `opt-base` model on the synthetic corpus with the FO substrate,
//! logging the LM loss curve, then ZO fine-tune it on a downstream task and
//! evaluate — proving all three layers (Pallas kernel, JAX model, Rust
//! coordinator) compose on a real workload.
//!
//! ```bash
//! cd python && python -m compile.aot --sizes opt-base   # optional (pjrt path)
//! cargo run --release --example e2e_train [pretrain_steps] [zo_steps]
//! ```
//!
//! With AOT artifacts present the run executes on the PJRT backend; without
//! them it runs entirely on the native backend (including pretraining, via
//! the native backward pass) — same pipeline, zero artifacts.
//!
//! Defaults (300 pretrain + 300 ZO steps) take tens of minutes on CPU; the
//! recorded run lives in EXPERIMENTS.md §E2E.

use anyhow::{Context, Result};
use lezo::config::{Method, RunConfig};
use lezo::coordinator::{trainer, Trainer};
use std::path::Path;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let pretrain_steps: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(300);
    let zo_steps: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(300);
    let dir = Path::new("artifacts/opt-base");

    // --- Phase 1: pretraining (~100M params, FO-Adam, synthetic corpus) ----
    let (spec, manifest) = lezo::runtime::backend::resolve_model("opt-base", dir)?;
    println!(
        "opt-base: {} params, {} layers, d_model {} ({})",
        spec.param_count(),
        spec.n_layers,
        spec.d_model,
        if manifest.is_some() { "AOT artifacts" } else { "native backend, no artifacts" }
    );
    let mut pcfg = RunConfig::default();
    pcfg.model = "opt-base".into();
    if dir.join("pretrained.ckpt").exists() {
        println!("pretrained.ckpt exists — skipping phase 1");
    } else {
        println!("\n== phase 1: pretraining for {pretrain_steps} steps ==");
        let (first, last) = trainer::pretrain(&pcfg, pretrain_steps, 6e-4, 0, 20)
            .context("pretraining opt-base")?;
        println!("LM loss: {first:.3} -> {last:.3}");
        anyhow::ensure!(last < first, "pretraining must reduce LM loss");
    }

    // --- Phase 2: ZO fine-tuning on SST-2-like, LeZO vs MeZO ---------------
    println!("\n== phase 2: ZO fine-tuning ({zo_steps} steps each) ==");
    let mut cfg = RunConfig::default();
    cfg.model = "opt-base".into();
    cfg.task = "sst2".into();
    cfg.steps = zo_steps;
    cfg.eval_every = (zo_steps / 4).max(1);
    cfg.eval_examples = 50;
    cfg.mu = 1e-3;

    let mut mezo = cfg.clone();
    mezo.method = Method::Mezo;
    mezo.lr = 5e-5;
    let rm = Trainer::new(mezo).run()?;

    let mut lezo = cfg.clone();
    lezo.method = Method::Lezo;
    lezo.drop_layers = 9; // 75% of opt-base's 12 blocks
    lezo.lr = 1.25e-4;
    let rl = Trainer::new(lezo).run()?;

    println!("\n== results ==");
    println!("{:<10}{:>10}{:>12}{:>14}", "method", "best acc", "ms/step", "non-forward");
    for (name, r) in [("MeZO", &rm), ("LeZO", &rl)] {
        println!(
            "{:<10}{:>9.1}%{:>12.0}{:>13.0}%",
            name,
            100.0 * r.best_metric,
            r.per_step_ms(),
            100.0 * r.stage_times.non_forward_fraction()
        );
    }
    println!(
        "\ncomputation speedup LeZO/MeZO: {:.2}x",
        rm.per_step_ms() / rl.per_step_ms()
    );
    println!("\nloss curves (first/last 5 steps):");
    for (name, r) in [("MeZO", &rm), ("LeZO", &rl)] {
        let n = r.losses.len();
        let head: Vec<String> = r.losses.iter().take(5).map(|l| format!("{l:.3}")).collect();
        let tail: Vec<String> =
            r.losses.iter().skip(n.saturating_sub(5)).map(|l| format!("{l:.3}")).collect();
        println!("  {name}: {} ... {}", head.join(" "), tail.join(" "));
    }
    Ok(())
}

//! ZO + PEFT (the paper's Table 4): fine-tune only LoRA adapters or prefix
//! KV positions with the ZO optimizer, with LeZO's layer-wise sparsity over
//! the per-block adapter units.
//!
//! ```bash
//! cargo run --release --example peft_finetune [lora|prefix] [steps]
//! ```

use anyhow::Result;
use lezo::config::{Method, RunConfig};
use lezo::coordinator::Trainer;
use lezo::model::Manifest;
use lezo::peft::PeftMode;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mode: PeftMode = args.first().map(|s| s.as_str()).unwrap_or("lora").parse()?;
    let steps: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(600);

    let model = "opt-micro";
    let manifest = Manifest::load(std::path::Path::new(&format!("artifacts/{model}")))?;
    let unit = match mode {
        PeftMode::Lora => manifest.lora_unit_len.expect("re-run make artifacts for PEFT"),
        PeftMode::Prefix => manifest.prefix_unit_len.expect("re-run make artifacts for PEFT"),
        PeftMode::Full => unreachable!(),
    };
    println!(
        "{model} + {mode}: {} tunable params ({} per block x {} blocks) vs {} total — {:.2}% of the model",
        unit * manifest.n_layers,
        unit,
        manifest.n_layers,
        manifest.param_count,
        100.0 * (unit * manifest.n_layers) as f64 / manifest.param_count as f64
    );

    let mut cfg = RunConfig::default();
    cfg.model = model.into();
    cfg.task = "sst2".into();
    cfg.peft = mode;
    cfg.steps = steps;
    cfg.eval_every = (steps / 4).max(1);
    cfg.eval_examples = 100;
    // Table-5 PEFT scales: much larger lr/mu than full-parameter ZO
    (cfg.lr, cfg.mu) = match mode {
        PeftMode::Lora => (5e-3, 1e-2),
        PeftMode::Prefix => (1e-2, 1e-1),
        PeftMode::Full => unreachable!(),
    };

    let mut mezo = cfg.clone();
    mezo.method = Method::Mezo;
    println!("\n== MeZO ({mode}) ==");
    let rm = Trainer::new(mezo).run()?;

    let mut lezo = cfg.clone();
    lezo.method = Method::Lezo;
    lezo.drop_layers = manifest.n_layers / 2; // Table 4: 50% for LoRA
    lezo.lr = cfg.lr * 2.0;
    println!("\n== LeZO ({mode}, drop {}/{}) ==", lezo.drop_layers, manifest.n_layers);
    let rl = Trainer::new(lezo).run()?;

    println!("\n{:<22}{:>10}{:>12}", "", "best acc", "ms/step");
    for (name, r) in [("MeZO", &rm), ("LeZO", &rl)] {
        println!("{:<22}{:>9.1}%{:>12.1}", name, 100.0 * r.best_metric, r.per_step_ms());
    }
    println!(
        "\nZO memory = base params + adapters only; adapters are {:.2}% of the model,\n\
         so perturb/update cost is negligible and the forward pass dominates.",
        100.0 * (unit * manifest.n_layers) as f64 / manifest.param_count as f64
    );
    Ok(())
}

//! ZO + PEFT (the paper's Table 4): fine-tune only LoRA adapters or prefix
//! KV positions with the ZO optimizer, with LeZO's layer-wise sparsity over
//! the per-block adapter units.
//!
//! Hermetic: with no artifacts exported this runs on the native backend's
//! adapter kernels; with an artifact set present (and a pjrt build) the
//! same code drives the AOT executables.
//!
//! ```bash
//! cargo run --release --example peft_finetune [lora|prefix] [steps]
//! ```

use anyhow::Result;
use lezo::config::{Method, RunConfig};
use lezo::coordinator::Trainer;
use lezo::peft::PeftMode;
use lezo::runtime::backend::{default_artifact_dir, resolve_model};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mode: PeftMode = args.first().map(|s| s.as_str()).unwrap_or("lora").parse()?;
    let steps: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(600);

    let model = "opt-micro";
    // manifest when artifacts exist, in-crate preset otherwise — the same
    // fallback rule the trainer uses, so this example needs no artifacts
    let (spec, manifest) = resolve_model(model, &default_artifact_dir(model))?;
    let unit = match mode {
        PeftMode::Lora => lezo::peft::lora_unit_len(spec.d_model),
        PeftMode::Prefix => lezo::peft::prefix_unit_len(spec.d_model),
        PeftMode::Full => unreachable!(),
    };
    println!(
        "{model} + {mode} ({}): {} tunable params ({} per block x {} blocks) vs {} total — \
         {:.2}% of the model",
        if manifest.is_some() { "AOT artifacts" } else { "native preset" },
        unit * spec.n_layers,
        unit,
        spec.n_layers,
        spec.param_count(),
        100.0 * (unit * spec.n_layers) as f64 / spec.param_count() as f64
    );

    let mut cfg = RunConfig::default();
    cfg.model = model.into();
    cfg.task = "sst2".into();
    cfg.peft = mode;
    cfg.steps = steps;
    cfg.eval_every = (steps / 4).max(1);
    cfg.eval_examples = 100;
    // Table-5 PEFT scales: much larger lr/mu than full-parameter ZO
    (cfg.lr, cfg.mu) = match mode {
        PeftMode::Lora => (5e-3, 1e-2),
        PeftMode::Prefix => (1e-2, 1e-1),
        PeftMode::Full => unreachable!(),
    };

    let mut mezo = cfg.clone();
    mezo.method = Method::Mezo;
    println!("\n== MeZO ({mode}) ==");
    let rm = Trainer::new(mezo).run()?;

    let mut lezo = cfg.clone();
    lezo.method = Method::Lezo;
    // Table-4 captions: LeZO drops 50% of blocks under LoRA, 75% under prefix
    lezo.drop_layers = match mode {
        PeftMode::Prefix => lezo::bench::paper_drop(spec.n_layers),
        _ => spec.n_layers / 2,
    };
    lezo.lr = cfg.lr * 2.0;
    println!("\n== LeZO ({mode}, drop {}/{}) ==", lezo.drop_layers, spec.n_layers);
    let rl = Trainer::new(lezo).run()?;

    println!("\n{:<22}{:>10}{:>12}", "", "best acc", "ms/step");
    for (name, r) in [("MeZO", &rm), ("LeZO", &rl)] {
        println!("{:<22}{:>9.1}%{:>12.1}", name, 100.0 * r.best_metric, r.per_step_ms());
    }
    println!(
        "\nZO memory = base params + adapters only; adapters are {:.2}% of the model,\n\
         so perturb/update cost is negligible and the forward pass dominates.",
        100.0 * (unit * spec.n_layers) as f64 / spec.param_count() as f64
    );
    Ok(())
}
